"""Observability layer (DESIGN.md §11): span tracer, metrics registry,
profiler hooks.

The contract under test is three-sided:

1. **Faithful**: a disturbed fault-injected serve run exports a valid
   Chrome/Perfetto trace from which every request's lifecycle is
   reconstructable, and the Prometheus export accounts for every submitted
   request with zero leaks (the ``counters_agree`` lockstep check).
2. **Invisible**: instrumented serving is bit-identical to uninstrumented —
   same tokens, same StepClock-driven deadline outcomes, no new compile-cache
   entries on the jitted decode programs.
3. **Cheap and host-only**: the per-span cost stays under the documented
   budget, ``repro.obs`` imports without jax, and the ``lint/obs-host-only``
   staticcheck rule keeps it that way structurally.
"""

import functools
import json
import math
import os
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

import repro
from repro.analysis.staticcheck import lint
from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import (
    Engine,
    Request,
    RequestState,
    Scheduler,
    SpecConfig,
    StepClock,
)
from repro.infer.lifecycle import RequestLifecycle, latency_summary
from repro.models import init_params, reduced
from repro.obs import (
    Histogram,
    MetricsRegistry,
    Tracer,
    default_registry,
    parse_prometheus,
    prometheus_text,
    validate_chrome_trace,
)
from repro.obs.metrics import counters_agree, exponential_buckets
from repro.obs.trace import demo_serve, request_lifecycles

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 64


def _cfg():
    return reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)


@functools.lru_cache(maxsize=None)
def _engine() -> Engine:
    return Engine(_cfg(), init_params(KEY, _cfg()), max_seq=MAX_SEQ)


def _requests(n=4, gen=6):
    """Fresh Request objects every call (rids are assigned at submit and are
    single-use per scheduler)."""
    cfg = _cfg()
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    out = []
    for i in range(n):
        plen = 4 + (i % 3)
        prompt = corpus.sample(1, plen, seed=50 + i)[0, :plen].astype(np.int32)
        out.append(
            Request(prompt=prompt, max_new_tokens=gen,
                    temperature=[0.0, 0.8][i % 2], seed=20 + i)
        )
    return out


# ---------------------------------------------------------------------------
# tracer unit behaviour
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering_under_stepclock():
    """Nested spans under a deterministic clock: exact enter/exit stamps,
    completion-ordered ring, annotations and instants land on the span."""
    clk = StepClock(dt=1.0)
    tr = Tracer(capacity=64, clock=clk)
    with tr.span("outer", lane="L", a=1) as outer:
        with tr.span("inner", lane="L"):
            pass  # enter reads t=1, exit reads t=2
        tr.instant("mark", lane="L")  # t=3
        outer.annotate(b=2)
    # ring holds completion order: inner closed before outer
    evs = tr.events()
    assert [(e[0], e[1]) for e in evs] == [
        ("X", "inner"), ("i", "mark"), ("X", "outer")
    ]
    inner, mark, outer_ev = evs
    assert (inner[4], inner[5]) == (1.0, 1.0)  # ts=1, dur=2-1
    assert mark[4] == 3.0
    assert (outer_ev[4], outer_ev[5]) == (0.0, 4.0)  # ts=0, dur=4-0
    assert outer_ev[6] == {"a": 1, "b": 2}


def test_span_records_exception_and_reraises():
    tr = Tracer(clock=StepClock(dt=1.0))
    with pytest.raises(RuntimeError, match="boom"):
        with tr.span("failing", lane="L"):
            raise RuntimeError("boom")
    (ev,) = tr.events()
    assert ev[1] == "failing"
    assert ev[6]["error"] == "RuntimeError: boom"


def test_ring_eviction_bounds_memory():
    tr = Tracer(capacity=4, clock=StepClock(dt=1.0))
    for i in range(10):
        tr.instant(f"i{i}", lane="L")
    st = tr.stats()
    assert st == {"recorded": 10, "buffered": 4, "evicted": 6, "capacity": 4}
    assert [e[1] for e in tr.events()] == ["i6", "i7", "i8", "i9"]
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_disabled_tracer_never_reads_its_clock():
    """tracer=None and Tracer(enabled=False) must be true zeros: a counting
    clock proves no readings happen, and span() hands back a shared no-op."""
    reads = []

    def clock():
        reads.append(1)
        return 0.0

    tr = Tracer(clock=clock, enabled=False)
    with tr.span("x", lane="L") as sp:
        sp.annotate(a=1)
    tr.instant("y")
    tr.complete("z", 0.0, 1.0)
    assert reads == []
    assert tr.stats()["recorded"] == 0
    assert tr.span("a") is tr.span("b")  # the shared null handle


def test_chrome_export_schema_valid_and_lanes_labelled():
    clk = StepClock(dt=0.5)
    tr = Tracer(clock=clk)
    with tr.span("decode_chunk", cat="scheduler", lane="scheduler", ordinal=0):
        pass
    tr.complete("queued", 0.25, 0.75, cat="lifecycle", lane="req:0")
    tr.instant("finished", lane="req:0", args={"rid": 0})
    trace = tr.to_chrome()
    assert validate_chrome_trace(trace) == []
    assert validate_chrome_trace(json.dumps(trace)) == []  # JSON round-trip
    events = [e for e in trace["traceEvents"] if e["ph"] != "M"]
    assert min(e["ts"] for e in events) == 0.0  # rebased to the earliest event
    # lane -> tid metadata lets Perfetto label the rows
    names = {
        e["args"]["name"]
        for e in trace["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert names == {"scheduler", "req:0"}
    assert trace["otherData"]["recorded"] == 3
    assert Tracer().chrome_events() == []  # empty tracer exports cleanly


def test_validate_chrome_trace_rejects_garbage():
    assert validate_chrome_trace("not json{") != []
    assert validate_chrome_trace([1, 2]) != []
    assert validate_chrome_trace({}) == ["missing or non-list 'traceEvents'"]
    bad = {
        "traceEvents": [
            {"ph": "Q", "name": "x", "pid": 1, "tid": 1, "ts": 0},
            {"ph": "X", "name": "", "pid": 1, "tid": 1, "ts": 0, "dur": 1},
            {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": -1, "dur": -2},
            {"ph": "i", "name": "x", "pid": "1", "tid": 1, "ts": 0, "s": "q"},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1},
        ]
    }
    problems = validate_chrome_trace(bad)
    assert len(problems) >= 6
    assert any("ph='Q'" in p for p in problems)


def test_request_lifecycles_groups_and_sorts_by_lane():
    tr = Tracer(clock=StepClock(dt=1.0))
    tr.complete("decoding", 5.0, 9.0, lane="req:1")
    tr.complete("queued", 0.0, 5.0, lane="req:1")
    tr.complete("queued", 1.0, 2.0, lane="req:2")
    tr.instant("mark", lane="scheduler")  # non-request lane: excluded
    lanes = request_lifecycles(tr.to_chrome())
    assert set(lanes) == {"req:1", "req:2"}
    assert [e["name"] for e in lanes["req:1"]] == ["queued", "decoding"]


# ---------------------------------------------------------------------------
# metrics registry unit behaviour
# ---------------------------------------------------------------------------


def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match=">= 0"):
        c.inc(-1)
    g = reg.gauge("queue_depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_histogram_bucketing_quantiles_and_nonfinite():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.cumulative() == [(1.0, 1), (2.0, 2), (4.0, 3), (math.inf, 4)]
    assert h.count == 4 and h.sum == pytest.approx(105.0)
    assert h.quantile(0.5) == 2.0
    assert h.quantile(1.0) == math.inf
    h.observe(float("nan"))  # must not poison sum/count
    assert h.nonfinite == 1 and h.count == 4
    assert Histogram().quantile(0.5) is None  # empty -> None, never NaN
    with pytest.raises(ValueError):
        Histogram(bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        exponential_buckets(start=0.0)
    bs = exponential_buckets(start=1.0, factor=2.0, count=3)
    assert bs == (1.0, 2.0, 4.0)


def test_registry_identity_and_morph_guards():
    reg = MetricsRegistry()
    a = reg.counter("hits_total", fmt="bcq")
    assert reg.counter("hits_total", fmt="bcq") is a  # get-or-create identity
    b = reg.counter("hits_total", fmt="uniform")  # new label set = new series
    assert b is not a
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("hits_total")  # kind morph
    with pytest.raises(ValueError, match="one name, one label set"):
        reg.counter("hits_total", impl="ref")  # label-key morph
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name")
    with pytest.raises(ValueError, match="invalid label name"):
        reg.counter("ok_total", **{"bad-label": "x"})


def test_registry_thread_safety_exact_totals():
    reg = MetricsRegistry()
    n_threads, per_thread = 8, 500

    def work():
        for i in range(per_thread):
            reg.counter("hits_total").inc()
            reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("hits_total").value == n_threads * per_thread
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    assert h.count == n_threads * per_thread
    assert h.cumulative()[0] == (0.1, n_threads * per_thread)


def test_prometheus_text_round_trips_through_parser():
    reg = MetricsRegistry()
    reg.counter("dispatch_total", "dispatches", fmt="bcq", impl="lutgemm").inc(7)
    reg.gauge("depth", "queue depth").set(3)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    text = prometheus_text(reg)
    assert "# TYPE dispatch_total counter" in text
    assert "# HELP lat_seconds latency" in text
    samples = parse_prometheus(text)
    assert samples["dispatch_total"] == [({"fmt": "bcq", "impl": "lutgemm"}, 7.0)]
    assert samples["depth"] == [({}, 3.0)]
    buckets = {ls["le"]: v for ls, v in samples["lat_seconds_bucket"]}
    assert buckets == {"0.1": 1.0, "1": 1.0, "+Inf": 2.0}
    assert samples["lat_seconds_count"] == [({}, 2.0)]
    assert samples["lat_seconds_sum"][0][1] == pytest.approx(5.05)
    # one scrape must not carry duplicate metric families
    other = MetricsRegistry()
    other.counter("dispatch_total").inc()
    with pytest.raises(ValueError, match="more than one registry"):
        prometheus_text(reg, other)


def test_parse_prometheus_is_strict():
    assert parse_prometheus("x_total 1\nx_total{a=\"b\"} +Inf\n") == {
        "x_total": [({}, 1.0), ({"a": "b"}, math.inf)]
    }
    for bad in ("no value here and no digits",
                "name{unclosed=\"x\" 1",
                "x_total notanumber",
                "# BOGUS comment kind"):
        with pytest.raises(ValueError):
            parse_prometheus(bad)


# ---------------------------------------------------------------------------
# latency_summary percentile edge cases (the satellite bugfix)
# ---------------------------------------------------------------------------


def test_latency_summary_empty_has_explicit_nulls():
    m = latency_summary([])
    assert m["requests"] == 0 and m["finished"] == 0
    for block in (m["ttft_s"], m["tpot_s"]):
        assert block["p50"] is None and block["p99"] is None
        assert block["mean"] is None and block["n"] == 0
    json.dumps(m)  # nulls survive JSON; NaN would raise here


def test_latency_summary_single_token_and_no_first_token():
    fin = RequestLifecycle(rid=0, submitted_at=0.0)
    fin.transition(RequestState.PREFILLING, 1.0)
    fin.transition(RequestState.DECODING, 2.0)
    fin.first_token_at = 3.0
    fin.n_tokens = 1  # single-token completion: TTFT exists, TPOT undefined
    fin.transition(RequestState.FINISHED, 4.0)
    dead = RequestLifecycle(rid=1, submitted_at=0.0)
    dead.transition(RequestState.CANCELLED, 1.0)  # terminal, never emitted
    m = latency_summary([fin, dead])
    assert m["requests"] == 2 and m["finished"] == 1
    assert m["no_first_token"] == 1
    assert m["ttft_s"]["n"] == 1 and m["ttft_s"]["p50"] == pytest.approx(3.0)
    assert m["ttft_s"]["excluded"] == 0
    # the single-token request has no TPOT: excluded, not NaN and not dropped
    assert m["tpot_s"]["n"] == 0 and m["tpot_s"]["excluded"] == 1
    assert m["tpot_s"]["p50"] is None
    json.dumps(m)


# ---------------------------------------------------------------------------
# scheduler integration: faithful under faults, invisible to tokens
# ---------------------------------------------------------------------------


def test_disturbed_serve_trace_reconstructs_and_metrics_account():
    """The acceptance run: a fault-injected serve (client cancel + NaN
    quarantine + deadline shed) must export a valid Chrome trace that
    reconstructs every request's lifecycle, and a Prometheus scrape in which
    every submitted request is accounted for — finished + cancelled +
    timed_out + shed + failed + rejected == submitted, agreeing exactly with
    the scheduler's own counters."""
    sched, tracer, registry = demo_serve()
    assert tracer.stats()["evicted"] == 0  # the window held the whole run

    trace = tracer.to_chrome()
    assert validate_chrome_trace(trace) == []
    lanes = request_lifecycles(json.dumps(trace))
    for rid, rec in sched.outcomes.items():
        lane = lanes.get(f"req:{rid}")
        assert lane is not None, f"request {rid} missing from the trace"
        names = [e["name"] for e in lane]
        assert names[0] == "submit"
        assert names[-1] == rec.state.value  # terminal instant closes the lane
        if rec.state is RequestState.FINISHED:
            # the full phase chain is reconstructable from the trace alone
            assert {"queued", "prefilling", "decoding"} <= set(names)
        # timestamps in a lane are monotone (sorted view of a causal chain)
        ts = [e["ts"] for e in lane]
        assert ts == sorted(ts)

    # the disturbances actually happened and were annotated
    by_state = {r.state.value for r in sched.outcomes.values()}
    assert {"finished", "failed", "cancelled", "shed"} <= by_state
    event_names = [e[1] for e in tracer.events()]
    assert "nan_quarantine" in event_names
    assert "decode_chunk" in event_names

    # zero-leak accounting, through the exact bytes a scraper would see
    samples = parse_prometheus(prometheus_text(registry))
    submitted = sum(v for _, v in samples["serve_submitted_total"])
    terminal = sum(
        sum(v for _, v in samples.get(f"serve_{k}_total", []))
        for k in ("finished", "cancelled", "timed_out", "shed", "failed",
                  "rejected_queue_full")
    )
    assert submitted == len(sched.outcomes) and submitted == terminal
    assert counters_agree(registry, sched.counters) == []
    # per-format kernel dispatch census rode along on the global registry
    fam = default_registry().snapshot().get("qmatmul_dispatch_total")
    assert fam is not None
    assert any(
        s["labels"].get("fmt") == "bcq" and s["value"] > 0 for s in fam["series"]
    )


def test_instrumented_serving_token_identical():
    eng = _engine()
    plain_sched = Scheduler(eng, n_slots=2, chunk=3)
    for r in _requests():
        plain_sched.submit(r)
    plain = {c.rid: c.new_tokens for c in plain_sched.run()}

    tr, reg = Tracer(), MetricsRegistry()
    sched = Scheduler(eng, n_slots=2, chunk=3, tracer=tr, metrics=reg)
    for r in _requests():
        sched.submit(r)
    instrumented = {c.rid: c.new_tokens for c in sched.run()}

    assert set(plain) == set(instrumented)
    for rid in plain:
        np.testing.assert_array_equal(plain[rid], instrumented[rid])
    assert tr.stats()["recorded"] > 0  # it did actually trace
    assert counters_agree(reg, sched.counters) == []
    total = sum(len(t) for t in plain.values())
    assert reg.counter("serve_tokens_total").value == total
    assert reg.gauge("serve_queue_depth").value == 0  # drained
    assert reg.histogram("serve_ttft_seconds").count == len(plain)


def test_tracing_does_not_perturb_stepclock_deadlines():
    """The tracer has its own clock precisely so recording spans never
    consumes scheduler clock readings — the deadline outcome of a
    StepClock-driven run must be identical with and without instrumentation."""
    eng = _engine()

    def run(instrumented):
        clk = StepClock(dt=0.05)
        kw = dict(clock=clk, sleep=clk.sleep)
        if instrumented:
            kw.update(tracer=Tracer(), metrics=MetricsRegistry())
        sched = Scheduler(eng, n_slots=1, chunk=2, **kw)
        reqs = _requests(n=3, gen=4)
        reqs[-1].deadline_s = 0.01  # sheds while earlier requests hold the slot
        for r in reqs:
            sched.submit(r)
        sched.run()
        return sched.summary()["by_state"], dict(sched.counters)

    assert run(False) == run(True)


def test_speculative_spans_account_for_draft_verify_rollback():
    from repro.quant import QuantPolicy, quantize_params

    cfg = _cfg()  # 128-dim: small enough to be fast, big enough to quantize
    params = quantize_params(
        init_params(KEY, cfg), QuantPolicy(q=3, g=32, iters=2)
    )
    tr, reg = Tracer(), MetricsRegistry()
    eng = Engine(cfg, params, max_seq=MAX_SEQ, tracer=tr)
    spec = SpecConfig(q_draft=2, gamma=2)
    sched = Scheduler(eng, n_slots=2, chunk=2, speculate=spec,
                      tracer=tr, metrics=reg)
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    for i in range(2):
        prompt = corpus.sample(1, 5, seed=300 + i)[0, :5].astype(np.int32)
        sched.submit(Request(prompt=prompt, max_new_tokens=6))
    done = sched.run()
    assert len(done) == 2

    verifies = [e for e in tr.events() if e[1] == "spec_verify"]
    assert verifies, "speculative run emitted no spec_verify annotations"
    committed_in_chunks = 0
    for ev in verifies:
        args = ev[6]
        assert args["drafted"] == spec.gamma
        assert 0 <= args["accepted"] <= spec.gamma
        assert args["accepted"] + args["rolled_back"] == spec.gamma
        assert ev[3].startswith("req:")  # attributed to the request's lane
        committed_in_chunks += args["accepted"] + 1
    # every chunk-committed token is accounted for by exactly one sub-chunk
    assert committed_in_chunks == sched.steps_active
    assert reg.gauge("serve_spec_accept_rate").value == pytest.approx(
        sched.spec_accept_rate
    )
    assert "engine/spec_chunks" in {e[1] for e in tr.events()}


# ---------------------------------------------------------------------------
# profiler hooks stay outside jit: no retrace, no host callbacks, host-only
# ---------------------------------------------------------------------------


def test_instrumentation_adds_no_compile_cache_entries():
    """An engine with a tracer attached must compile exactly the same
    programs: two identical-shape generations leave each jitted entry with
    at most one compile-cache entry (the staticcheck trace-once contract),
    and the tokens match the uninstrumented engine bit-for-bit."""
    tr = Tracer()
    eng = Engine(_cfg(), init_params(KEY, _cfg()), max_seq=MAX_SEQ, tracer=tr)
    corpus = MarkovCorpus(_cfg().vocab, seed=3)
    p = corpus.sample(1, 5, seed=400)[0, :5].astype(np.int32)
    out = eng.generate(p[None], 6)
    eng.generate(corpus.sample(1, 5, seed=401)[0, :5].astype(np.int32)[None], 6)
    for name in ("_prefill", "_scan_decode"):
        size = getattr(eng, name)._cache_size()
        assert size <= 1, f"{name} retraced under instrumentation ({size})"
    # host-side spans were recorded around (not inside) the dispatches
    names = {e[1] for e in tr.events()}
    assert {"engine/prefill", "engine/scan_decode"} <= names
    solo = _engine().generate(p[None], 6)
    np.testing.assert_array_equal(out.tokens, solo.tokens)


def test_obs_package_imports_without_jax():
    """repro.obs is host-side-only: importing it must not pull jax (the
    structural guarantee behind 'instrumentation cannot touch device
    state'). Run in a subprocess so this module's own jax import doesn't
    mask a regression."""
    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ, PYTHONPATH=src_dir)
    code = (
        "import sys; import repro.obs; "
        "bad = sorted(m for m in sys.modules if m == 'jax' or "
        "m.startswith('jax.')); "
        "assert not bad, f'repro.obs pulled {bad[:3]}'"
    )
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


def test_obs_host_only_lint_rule():
    bad = (
        "import jax\n"
        "from repro.kernels import ops\n"
        "try:\n"
        "    import repro.models\n"
        "except ImportError:\n"
        "    pass\n"
        "def demo():\n"
        "    import jax  # lazy: allowed\n"
    )
    hits = [v for v in lint.lint_source(bad, "obs/bad.py")
            if v.passname == "lint/obs-host-only"]
    assert sorted(int(v.where.split(":")[1]) for v in hits) == [1, 2, 4]
    good = "import json\ndef demo():\n    from repro.infer import Engine\n"
    assert lint.lint_source(good, "obs/good.py") == []
    # the rule is scoped to obs/ — the hot-path dirs legitimately import jax
    assert not [v for v in lint.lint_source("import jax\n", "infer/x.py")
                if v.passname == "lint/obs-host-only"]


def test_repo_lint_clean_including_obs_rule():
    """The instrumented stack stays lint-clean: every new host sync is
    declared, and the obs package never imports the jitted stack."""
    result = lint.run()
    assert result.checked > 0
    assert result.violations == [], "\n".join(str(v) for v in result.violations)


def test_tracer_overhead_within_budget():
    """DESIGN.md §11 budget: a recorded span costs two clock readings and a
    deque append — single-digit µs typical. Asserted against a 50x slack
    bound so a loaded CI host never flakes, while a pathological regression
    (formatting per record, lock convoy) still fails."""
    tr = Tracer(capacity=100_000)
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        with tr.span("bench", lane="bench", i=i):
            pass
    per_span = (time.perf_counter() - t0) / n
    assert tr.stats()["recorded"] == n
    assert per_span < 100e-6, f"{per_span * 1e6:.1f} µs/span exceeds budget"


# ---------------------------------------------------------------------------
# server export surfaces
# ---------------------------------------------------------------------------


def _go(coro, timeout=120.0):
    import asyncio

    return asyncio.run(asyncio.wait_for(coro, timeout))


def test_session_exports_prometheus_and_trace():
    from repro.launch.server import ServeSession

    eng = _engine()
    (req,) = _requests(n=1, gen=5)

    async def run():
        async with ServeSession(eng, n_slots=2, chunk=3) as sess:
            stream = await sess.submit_stream(req)
            await stream.drain()
            return sess.metrics(), sess.prometheus(), sess.trace_json()

    m, text, trace = _go(run())
    assert "registry" in m and "tracer" in m
    assert m["tracer"]["recorded"] > 0
    samples = parse_prometheus(text)
    assert sum(v for _, v in samples["serve_submitted_total"]) == 1
    assert sum(v for _, v in samples["serve_finished_total"]) == 1
    assert "serve_slot_capacity" in samples
    assert validate_chrome_trace(trace) == []
    assert "req:0" in request_lifecycles(trace)

    dark = ServeSession(eng, observe=False)
    assert dark.tracer is None and dark.registry is None
    with pytest.raises(RuntimeError, match="no metrics registry"):
        dark.prometheus()
    with pytest.raises(RuntimeError, match="no tracer"):
        dark.trace_json()


def test_http_prometheus_and_trace_endpoints():
    aiohttp = pytest.importorskip("aiohttp")
    from repro.launch.server import ServeSession, bound_port, run_server

    eng = _engine()
    (req,) = _requests(n=1, gen=4)

    async def run():
        session = ServeSession(eng, n_slots=1, chunk=2)
        async with session:
            runner = await run_server(session, port=0)
            base = f"http://127.0.0.1:{bound_port(runner)}"
            try:
                stream = await session.submit_stream(req)
                await stream.drain()
                async with aiohttp.ClientSession() as client:
                    async with client.get(
                        f"{base}/v1/metrics", params={"format": "prometheus"}
                    ) as r:
                        assert r.status == 200
                        assert r.content_type == "text/plain"
                        text = await r.text()
                    async with client.get(f"{base}/v1/metrics") as r:
                        summary = await r.json()
                    async with client.get(f"{base}/v1/trace") as r:
                        trace = await r.json()
            finally:
                await runner.cleanup()
        return text, summary, trace

    text, summary, trace = _go(run(), timeout=180.0)
    samples = parse_prometheus(text)
    assert sum(v for _, v in samples["serve_finished_total"]) == 1
    # the scrape merges the process-global registry: kernel dispatch counts
    # ride along when any quantized model ran in this process (not asserted
    # present — this engine is dense)
    assert summary["by_state"] == {"finished": 1}
    assert "registry" in summary and "tracer" in summary
    assert validate_chrome_trace(trace) == []
    assert request_lifecycles(trace)  # at least the served request's lane
