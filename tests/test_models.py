"""Per-architecture smoke tests (reduced configs, CPU) + cached-decode
consistency. Covers deliverable (f)'s smoke requirement for all 10 archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import forward, init_cache, init_params, reduced

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _inputs(cfg, s=S):
    kwargs = {}
    if cfg.input_kind == "tokens":
        kwargs["tokens"] = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
    else:
        kwargs["embeddings"] = jax.random.normal(KEY, (B, s, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        kwargs["image_emb"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32
        )
    return kwargs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = reduced(get_config(arch))
    params = init_params(KEY, cfg)
    logits, _, aux = forward(cfg, params, **_inputs(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert not bool(jnp.isnan(aux)), f"{arch}: NaN aux"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    from repro.train import adamw_init, make_train_step

    cfg = reduced(get_config(arch))
    params = init_params(KEY, cfg)
    opt = adamw_init(params)
    batch = dict(_inputs(cfg))
    batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    step = jax.jit(make_train_step(cfg, remat=True, lr=1e-3))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, f"{arch}: optimizer step did not change params"


@pytest.mark.parametrize(
    "arch,tol",
    [
        ("llama3.2-3b", 2e-4),
        ("starcoder2-7b", 2e-4),
        ("musicgen-medium", 2e-4),
        ("recurrentgemma-9b", 5e-4),
        ("xlstm-125m", 5e-4),
        ("llama-3.2-vision-90b", 5e-4),
    ],
)
def test_prefill_decode_matches_train_forward(arch, tol):
    """Cached prefill+decode logits must equal the full forward's."""
    cfg = reduced(get_config(arch))
    params = init_params(KEY, cfg)
    kwargs = _inputs(cfg)
    full, _, _ = forward(cfg, params, **kwargs)
    cache = init_cache(cfg, B, S)
    pre = {
        k: (v if k == "image_emb" else v[:, : S - 1]) for k, v in kwargs.items()
    }
    lp, cache, _ = forward(
        cfg, params, **pre, cache=cache, pos=jnp.int32(0), logits_mode="last"
    )
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(full[:, S - 2]), rtol=tol, atol=tol
    )
    last = {
        k: (None if k == "image_emb" else v[:, S - 1 :]) for k, v in kwargs.items()
    }
    lp2, _, _ = forward(
        cfg, params, **last, cache=cache, pos=jnp.int32(S - 1), logits_mode="last"
    )
    np.testing.assert_allclose(
        np.asarray(lp2[:, 0]), np.asarray(full[:, S - 1]), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "llama4-maverick-400b-a17b"])
def test_moe_consistency_at_no_drop_capacity(arch):
    base = reduced(get_config(arch))
    cfg = reduced(
        get_config(arch), capacity_factor=float(base.n_experts / base.top_k)
    )
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full, _, _ = forward(cfg, params, tokens=toks)
    cache = init_cache(cfg, B, S)
    lp, cache, _ = forward(
        cfg, params, tokens=toks[:, : S - 1], cache=cache, pos=jnp.int32(0),
        logits_mode="last",
    )
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(full[:, S - 2]), rtol=1e-3, atol=1e-3
    )


def test_long_context_support_flags():
    sub_quadratic = {a for a in ARCH_IDS if get_config(a).supports_long_context}
    assert sub_quadratic == {"recurrentgemma-9b", "xlstm-125m"}


def test_local_attention_ring_decode_beyond_window():
    """Decode past the window: ring buffer must keep only the last `window`."""
    cfg = reduced(get_config("recurrentgemma-9b"), window=8)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, 24), 0, cfg.vocab)
    full, _, _ = forward(cfg, params, tokens=toks)
    cache = init_cache(cfg, B, 24)
    lp, cache, _ = forward(
        cfg, params, tokens=toks[:, :-1], cache=cache, pos=jnp.int32(0),
        logits_mode="last",
    )
    lp2, _, _ = forward(
        cfg, params, tokens=toks[:, -1:], cache=cache, pos=jnp.int32(23),
        logits_mode="last",
    )
    np.testing.assert_allclose(
        np.asarray(lp2[:, 0]), np.asarray(full[:, -1]), rtol=1e-3, atol=1e-3
    )


def test_exact_configs_match_assignment():
    """The full (non-reduced) configs carry the assigned hyper-parameters."""
    expect = {
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
            L, d, h, kv, ff, v,
        ), arch
    assert get_config("olmoe-1b-7b").n_experts == 64
    assert get_config("olmoe-1b-7b").top_k == 8
    assert get_config("llama4-maverick-400b-a17b").n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").top_k == 1
    assert get_config("llama4-maverick-400b-a17b").shared_expert
    assert get_config("recurrentgemma-9b").window == 2048


def test_int8_kv_cache_decode_close_to_exact():
    """Beyond-paper int8 KV cache: decode logits within 5% of the bf16 cache."""
    import dataclasses

    cfg = reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8", stages=None)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full, _, _ = forward(cfg, params, tokens=toks)
    cache = init_cache(cfg8, B, S)
    assert cache["stages"][0]["b0"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["stages"][0]["b0"]
    _, cache, _ = forward(
        cfg8, params, tokens=toks[:, : S - 1], cache=cache, pos=jnp.int32(0),
        logits_mode="last",
    )
    lp2, _, _ = forward(
        cfg8, params, tokens=toks[:, S - 1 :], cache=cache, pos=jnp.int32(S - 1),
        logits_mode="last",
    )
    rel = float(
        jnp.linalg.norm(lp2[:, 0] - full[:, S - 1]) / jnp.linalg.norm(full[:, S - 1])
    )
    assert rel < 0.05, rel
