"""Tensor-parallel serving (ISSUE 4 tentpole): the cross-config differential
harness. A `shard_map`-sharded engine (weights column/row-parallel, KV cache
kv-head-sharded — DESIGN.md §7) must be *invisible* at the token level:

- **greedy decode is bit-identical** to the single-device engine for every
  (precision × path × tp) cell. Column-parallel projections compute each
  output element from the full reduction dim, so they are bitwise equal;
  row-parallel projections psum partial sums, which only reassociates the
  f32 reduction — logits move by ~1e-5, never enough to flip an argmax on
  continuously-distributed random logits.
- **logits are close, not bitwise**, for temperature sampling: the psum
  reassociation bound (see `test_logits_close_to_single_device`) justifies
  the tolerance.
- slot-batched serving and speculative decoding inherit both properties,
  because every path funnels through the same sharded forward.

Engines are cached per (q, tp, fuse) because each construction compiles its
own prefill/scan graphs; all tests reuse the same prompts and step counts so
the jit caches stay warm across the module.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import Engine, Request, Scheduler, SpecConfig
from repro.models import init_params, reduced
from repro.parallel.tp import make_tp_mesh
from repro.quant import QuantPolicy, quantize_params

pytestmark = pytest.mark.needs_multidevice

KEY = jax.random.PRNGKey(0)
N_STEPS = 8
MAX_SEQ = 48

# d_model=128 so quantization actually bites (quantize_params skips <128-dim
# linears); g=32 keeps (k/g) divisible by tp=4 for the row-parallel wo
# (k=q_dim=128 → k/g=4) and w_down (k=d_ff=256 → k/g=8)
Q_GROUP = 32


def _cfg():
    return reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)


@functools.lru_cache(maxsize=None)
def _params(q: int):
    params = init_params(KEY, _cfg())
    if q:
        params = quantize_params(params, QuantPolicy(q=q, g=Q_GROUP, iters=2))
    return params


@functools.lru_cache(maxsize=None)
def _engine(q: int, tp: int, fuse: bool = True) -> Engine:
    """tp=0 → the plain single-device engine (the differential reference)."""
    mesh = make_tp_mesh(tp) if tp else None
    return Engine(_cfg(), _params(q), max_seq=MAX_SEQ, mesh=mesh, fuse=fuse)


@functools.lru_cache(maxsize=None)
def _prompts():
    cfg = _cfg()
    return MarkovCorpus(cfg.vocab, seed=3).sample(2, 6, seed=1).astype(np.int32)


@functools.lru_cache(maxsize=None)
def _ref_tokens(q: int):
    return _engine(q, 0).generate(_prompts(), N_STEPS).tokens


# ---------------------------------------------------------------------------
# greedy decode: bit-identical tokens across the (precision × tp) grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tp", [1, 2, 4])
@pytest.mark.parametrize("q", [0, 2, 4], ids=["dense", "bcq2", "bcq4"])
def test_greedy_tokens_bit_identical(q, tp):
    out = _engine(q, tp).generate(_prompts(), N_STEPS)
    np.testing.assert_array_equal(out.tokens, _ref_tokens(q))


@pytest.mark.parametrize("tp", [2, 4])
def test_unfused_engine_greedy_identical(tp):
    """The per-projection (non-wqkv) kernel layout shards without the fused
    column re-interleave and must produce the same tokens."""
    out = _engine(4, tp, fuse=False).generate(_prompts(), N_STEPS)
    np.testing.assert_array_equal(out.tokens, _ref_tokens(4))


@pytest.mark.parametrize("scan", [True, False], ids=["scan", "steploop"])
def test_tp_scan_and_steploop_agree(scan):
    """Within one TP engine the scanned and per-step decode paths stay
    bit-identical (the PR 1 invariant survives sharding)."""
    out = _engine(4, 2).generate(_prompts(), N_STEPS, scan=scan)
    np.testing.assert_array_equal(out.tokens, _ref_tokens(4))


def test_tp1_sampled_bitwise():
    """A 1-device mesh runs the full shard_map machinery but psums over a
    single shard — even *sampled* output must match the plain engine
    bit-for-bit."""
    ref = _engine(4, 0).generate(_prompts(), N_STEPS, temperature=1.0, seed=7)
    out = _engine(4, 1).generate(_prompts(), N_STEPS, temperature=1.0, seed=7)
    np.testing.assert_array_equal(out.tokens, ref.tokens)


def test_tp_sampled_internally_deterministic():
    """Sampled decode on a sharded engine is deterministic: logits are
    replicated post-gather, so the PRNG stream consumes identical values on
    every device and across runs."""
    eng = _engine(4, 2)
    a = eng.generate(_prompts(), N_STEPS, temperature=0.7, seed=11)
    b = eng.generate(_prompts(), N_STEPS, temperature=0.7, seed=11)
    np.testing.assert_array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# logits: close up to psum reassociation (the temperature-sampling contract)
# ---------------------------------------------------------------------------

# Tolerance: row-parallel projections (wo, w_down) psum tp partial sums, which
# reassociates an f32 reduction of length k∈{128, 256}. Per element the error
# is bounded by ~(tp-1)·eps·Σ|terms| with eps=2^-24 and activation terms O(1),
# i.e. ~1e-5 per projection; two blocks + lm_head compound it. 1e-3 abs/rel
# leaves ~100x headroom over the observed ~1e-5 while still catching any real
# sharding bug (a wrong shard produces O(1) errors).
LOGIT_TOL = 1e-3


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("q", [0, 4], ids=["dense", "bcq4"])
def test_logits_close_to_single_device(q, tp):
    ref, eng = _engine(q, 0), _engine(q, tp)
    p = jnp.asarray(_prompts())
    l_ref, c_ref = ref._prefill(ref.params, p, None, ref._make_cache(2))
    l_tp, c_tp = eng._prefill(eng.params, p, None, eng._make_cache(2))
    np.testing.assert_allclose(
        np.asarray(l_tp), np.asarray(l_ref), rtol=LOGIT_TOL, atol=LOGIT_TOL
    )
    tok = jnp.asarray([[3], [5]], jnp.int32)
    d_ref, _ = ref._decode(ref.params, tok, c_ref, jnp.int32(6))
    d_tp, _ = eng._decode(eng.params, tok, c_tp, jnp.int32(6))
    np.testing.assert_allclose(
        np.asarray(d_tp), np.asarray(d_ref), rtol=LOGIT_TOL, atol=LOGIT_TOL
    )


# ---------------------------------------------------------------------------
# slot-batched continuous serving on the sharded engine
# ---------------------------------------------------------------------------


def _greedy_requests(n):
    cfg = _cfg()
    corpus = MarkovCorpus(cfg.vocab, seed=9)
    lens = [4, 6, 4, 6, 5]
    buds = [5, 7, 7, 5, 7]
    return [
        Request(
            prompt=corpus.sample(1, lens[i], seed=50 + i)[0].astype(np.int32),
            max_new_tokens=buds[i],
        )
        for i in range(n)
    ]


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("q", [0, 4], ids=["dense", "bcq4"])
def test_slot_scheduler_tokens_identical(q, tp):
    """Continuous batching over the sharded engine, with mid-flight admission
    (5 requests through 2 slots), against SOLO generates on the single-device
    engine — the two invariants (slot invisibility + TP invisibility)
    composed."""
    reqs = _greedy_requests(5)
    sched = Scheduler(_engine(q, tp), n_slots=2, chunk=3)
    for r in reqs:
        sched.submit(r)
    done = {c.rid: c for c in sched.run()}
    assert len(done) == len(reqs)
    ref = _engine(q, 0)
    for r in reqs:
        solo = ref.generate(r.prompt[None], r.max_new_tokens)
        np.testing.assert_array_equal(
            done[r.rid].new_tokens, solo.tokens[0, r.prompt.size :],
            err_msg=f"request {r.rid} diverged from single-device solo",
        )


@pytest.mark.parametrize("tp", [2, 4])
def test_slot_scheduler_mixed_temps_match_tp_solo(tp):
    """Sampled rows can't be compared against the *single-device* engine
    bit-for-bit (psum reassociation shifts logits under the categorical), but
    slot-batching must stay invisible WITHIN the sharded engine: each
    request's tokens equal a solo generate on the same TP engine."""
    eng = _engine(4, tp)
    reqs = _greedy_requests(4)
    for i, r in enumerate(reqs):
        r.temperature = [0.0, 1.0, 0.7, 0.0][i]
        r.seed = 20 + i
    sched = Scheduler(eng, n_slots=2, chunk=3)
    for r in reqs:
        sched.submit(r)
    done = {c.rid: c for c in sched.run()}
    for r in reqs:
        solo = eng.generate(
            r.prompt[None], r.max_new_tokens, temperature=r.temperature, seed=r.seed
        )
        np.testing.assert_array_equal(
            done[r.rid].new_tokens, solo.tokens[0, r.prompt.size :]
        )


# ---------------------------------------------------------------------------
# speculative decoding on the sharded engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("q,q_draft", [(2, 1), (4, 2)], ids=["bcq2_d1", "bcq4_d2"])
def test_speculative_greedy_identical(q, q_draft, tp):
    """Draft-verify-rollback on sharded params (the draft is a plane-slice of
    the SAME sharded weights) must reproduce plain greedy decode exactly —
    which the single-device reference already equals."""
    out = _engine(q, tp).generate(
        _prompts(), N_STEPS, speculate=SpecConfig(q_draft=q_draft, gamma=2)
    )
    np.testing.assert_array_equal(out.tokens, _ref_tokens(q))
    assert out.spec_stats["chunks"] >= 1


@functools.lru_cache(maxsize=None)
def _fmt_params(fmt: str):
    return quantize_params(
        init_params(KEY, _cfg()), QuantPolicy(q=3, g=Q_GROUP, iters=2, fmt=fmt)
    )


@pytest.mark.parametrize("tp", [1, 2])
@pytest.mark.parametrize("fmt", ["codebook", "ternary"])
def test_new_format_tp_greedy_identical(fmt, tp):
    """The PR 9 formats shard through the same generic tp_specs rule: greedy
    tokens on a 1- and 2-way mesh stay bit-identical to the plain engine."""
    qp = _fmt_params(fmt)
    ref = Engine(_cfg(), qp, max_seq=MAX_SEQ).generate(_prompts(), N_STEPS)
    out = Engine(_cfg(), qp, max_seq=MAX_SEQ, mesh=make_tp_mesh(tp)).generate(
        _prompts(), N_STEPS
    )
    np.testing.assert_array_equal(out.tokens, ref.tokens)


def test_ternary_speculative_tp_greedy_identical():
    """Ternary self-speculation (the nested 1-plane BCQ draft from the
    masked-BCQ identity) on a sharded engine reproduces plain greedy."""
    qp = _fmt_params("ternary")
    ref = Engine(_cfg(), qp, max_seq=MAX_SEQ).generate(_prompts(), N_STEPS)
    out = Engine(_cfg(), qp, max_seq=MAX_SEQ, mesh=make_tp_mesh(2)).generate(
        _prompts(), N_STEPS, speculate=SpecConfig(q_draft=1, gamma=2)
    )
    np.testing.assert_array_equal(out.tokens, ref.tokens)


def test_speculative_slot_scheduler_tp():
    """Speculative continuous batching (draft cache + pending tokens all
    sharded) against single-device solo greedy."""
    eng = _engine(4, 2)
    reqs = _greedy_requests(4)
    sched = Scheduler(eng, n_slots=2, chunk=2, speculate=SpecConfig(q_draft=2, gamma=2))
    for r in reqs:
        sched.submit(r)
    done = {c.rid: c for c in sched.run()}
    ref = _engine(4, 0)
    for r in reqs:
        solo = ref.generate(r.prompt[None], r.max_new_tokens)
        np.testing.assert_array_equal(
            done[r.rid].new_tokens, solo.tokens[0, r.prompt.size :]
        )


def test_draft_truncation_preserves_sharding():
    """`truncate_params` slices BCQ planes along q — never the sharded dim —
    so the draft view must keep the full tree's NamedShardings."""
    from repro.core.qtensor import QuantizedTensor

    eng = _engine(4, 2)
    draft = eng.draft_params(2)

    full_leaves = jax.tree.leaves(eng.params)
    draft_leaves = jax.tree.leaves(draft)
    assert len(full_leaves) == len(draft_leaves)
    checked = 0
    for f, d in zip(full_leaves, draft_leaves):
        if f.shape != d.shape:  # a truncated plane: q axis halved
            assert f.sharding.spec == d.sharding.spec
            checked += 1
    assert checked > 0, "no truncated leaves found — draft equals target?"


def test_kv_cache_sharded_over_heads():
    """The slot cache's k/v leaves carry `model` on the kv-head dim
    (R, B, S, Hkv, Dh) and nowhere else; counters stay replicated."""
    eng = _engine(4, 2)
    slots = eng.init_slots(2)
    k = slots["cache"]["stages"][0]["b0"]["k"]
    assert tuple(k.sharding.spec) == (None, None, None, "model", None)
    assert np.asarray(slots["pos"]).shape == (2,)


# ---------------------------------------------------------------------------
# loud failures instead of silent replication / wrong shards
# ---------------------------------------------------------------------------


def test_rejects_indivisible_scale_groups():
    """g=128 on a k=128 row-parallel wo gives one scale group — unsplittable
    at tp=2. shard_model must refuse, naming the leaf and the dims."""
    params = quantize_params(init_params(KEY, _cfg()), QuantPolicy(q=2, g=128, iters=1))
    with pytest.raises(ValueError, match=r"wo.*k/g|k/g.*wo"):
        Engine(_cfg(), params, max_seq=MAX_SEQ, mesh=make_tp_mesh(2))


def test_rejects_indivisible_heads():
    cfg = reduced(get_config("llama3.2-3b"))  # n_kv_heads=2
    with pytest.raises(ValueError, match="n_kv_heads"):
        Engine(cfg, init_params(KEY, cfg), max_seq=MAX_SEQ, mesh=make_tp_mesh(4))


def test_rejects_recurrent_family():
    cfg = reduced(get_config("recurrentgemma-9b"))
    with pytest.raises(NotImplementedError, match="rglru"):
        Engine(cfg, init_params(KEY, cfg), max_seq=MAX_SEQ, mesh=make_tp_mesh(2))


def test_rejects_moe_family():
    cfg = reduced(get_config("olmoe-1b-7b"))
    with pytest.raises(NotImplementedError, match="attn_moe"):
        Engine(cfg, init_params(KEY, cfg), max_seq=MAX_SEQ, mesh=make_tp_mesh(2))


def test_mesh_needs_enough_devices():
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_tp_mesh(64)
