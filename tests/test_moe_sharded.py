"""shard_map MoE dispatch (the §Perf cell-B fix) — equivalence with the global
reference under a real multi-device mesh (subprocess; 8 placeholder devices)."""

import subprocess
import sys

import pytest

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import set_mesh
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, _moe_apply_global, moe_apply

cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, n_experts=8, top_k=2,
                  capacity_factor=4.0, moe_d_ff=64,
                  param_dtype="float32", compute_dtype="float32")
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16, 32)), jnp.float32)
y_ref, _ = _moe_apply_global(p, cfg, x)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    y_sh, _ = jax.jit(lambda p, x: moe_apply(p, cfg, x))(p, x)
np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref), rtol=2e-4, atol=2e-4)

# gradients flow through the psum/shard_map path
with set_mesh(mesh):
    g = jax.jit(jax.grad(lambda p, x: moe_apply(p, cfg, x)[0].sum()))(p, x)
assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))

# shared-expert variant
cfg2 = ModelConfig(name="t2", family="moe", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab=64, n_experts=8, top_k=1,
                   capacity_factor=8.0, shared_expert=True, moe_d_ff=64,
                   param_dtype="float32", compute_dtype="float32")
p2 = init_moe(jax.random.PRNGKey(1), cfg2)
y2_ref, _ = _moe_apply_global(p2, cfg2, x)
with set_mesh(mesh):
    y2_sh, _ = jax.jit(lambda p, x: moe_apply(p, cfg2, x))(p2, x)
np.testing.assert_allclose(np.asarray(y2_sh), np.asarray(y2_ref), rtol=2e-4, atol=2e-4)
print("MOE-SHARDED-OK")
"""


@pytest.mark.slow
def test_sharded_moe_matches_global_reference():
    out = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True, timeout=600
    )
    assert "MOE-SHARDED-OK" in out.stdout, out.stderr[-3000:]
