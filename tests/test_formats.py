"""Pluggable quantization-format API (core/formats.py, DESIGN.md §2.4).

Per-format property tests (pack→dequant round-trip bounds, nbytes
accounting, registry errors), the cross-format differential (greedy tokens
for ``dequant`` vs ``uniform`` at the same (q, g) are bit-identical — same
packing, different kernel pipeline), capability gating (truncate/fuse), and
the temperature-guard regression for ``Engine._sample`` / ``Request``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    QuantizedTensor,
    format_names,
    get_format,
    pack_codes,
    quantize_tensor,
    unpack_codes,
)
from repro.data import MarkovCorpus
from repro.infer import Engine, Request, Scheduler, SpecConfig
from repro.infer.engine import _sample
from repro.kernels import qmatmul
from repro.kernels.autotune import get_blocks, make_key
from repro.models import init_params, reduced
from repro.quant import (
    QuantPolicy,
    quantize_params,
    quantized_structs,
    truncate_params,
)

KEY = jax.random.PRNGKey(0)
FORMATS = ("bcq", "uniform", "dequant")


def _w(rng, k=256, o=128):
    return jnp.asarray(rng.standard_normal((k, o)), jnp.float32)


def _small_cfg():
    return reduced(
        get_config("llama3.2-3b"), d_model=256, n_kv_heads=4, d_ff=512
    )


def _prompts(cfg, b, s, seed=3):
    return MarkovCorpus(cfg.vocab, seed=seed).sample(b, s, seed=7)[:, :s].astype(
        np.int32
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_names_and_lookup():
    assert set(FORMATS) <= set(format_names())
    for name in FORMATS:
        assert get_format(name).name == name
    with pytest.raises(ValueError, match="unknown quantization format"):
        get_format("nope")
    # the error names the registered formats so the fix is self-evident
    with pytest.raises(ValueError, match="bcq"):
        get_format("int3")


def test_quantize_tensor_tags_format(rng):
    w = _w(rng)
    for fmt in FORMATS:
        qt = quantize_tensor(w, q=4, g=64, method="greedy", fmt=fmt)
        assert qt.fmt == fmt
        assert qt.shape == (256, 128)
        assert qt.format() is get_format(fmt)


# ---------------------------------------------------------------------------
# pack → dequant round trips
# ---------------------------------------------------------------------------


def test_pack_unpack_codes_roundtrip(rng):
    for q in (2, 4, 8):
        codes = jnp.asarray(rng.integers(0, 2**q, (64, 24)), jnp.uint8)
        packed = pack_codes(codes, q)
        assert packed.shape == (q, 8, 24)
        assert packed.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(unpack_codes(packed)), codes)


def test_uniform_roundtrip_error_bound(rng):
    """Affine group quantization: |w - ŵ| <= scale/2 per element (f32 scales),
    with scale = (max - min) / (2^q - 1) per (group, column)."""
    w = _w(rng, k=256, o=64)
    g = 64
    for q in (2, 4, 8):
        qt = quantize_tensor(w, q=q, g=g, scale_dtype=jnp.float32, fmt="uniform")
        w_hat = qt.dequantize()
        grouped = np.asarray(w).reshape(256 // g, g, 64)
        scale = np.maximum(
            (grouped.max(1) - grouped.min(1)) / (2**q - 1), 1e-8
        )  # (G, o)
        err = np.abs(np.asarray(w_hat) - np.asarray(w)).reshape(256 // g, g, 64)
        assert np.all(err <= scale[:, None, :] * 0.5 + 1e-5), f"q={q}"


def test_roundtrip_error_monotone_in_q(rng):
    w = _w(rng)
    for fmt in ("bcq", "uniform"):
        errs = []
        for q in (2, 4, 8):
            qt = quantize_tensor(
                w, q=q, g=64, method="greedy", scale_dtype=jnp.float32, fmt=fmt
            )
            errs.append(
                float(jnp.linalg.norm(qt.dequantize() - w) / jnp.linalg.norm(w))
            )
        assert errs[0] > errs[1] > errs[2], (fmt, errs)


# ---------------------------------------------------------------------------
# nbytes accounting
# ---------------------------------------------------------------------------


def test_nbytes_accounting(rng):
    k, o, q, g = 256, 128, 4, 64
    w = _w(rng, k, o)
    for dtype, itemsize in ((jnp.float32, 4), (jnp.bfloat16, 2)):
        bcq = quantize_tensor(w, q=q, g=g, method="greedy", scale_dtype=dtype)
        assert bcq.nbytes() == q * (k // 8) * o + q * (k // g) * o * itemsize
        uni = quantize_tensor(w, q=q, g=g, scale_dtype=dtype, fmt="uniform")
        assert uni.nbytes() == q * (k // 8) * o + 2 * (k // g) * o * itemsize
        # dequant shares uniform's packing byte-for-byte
        deq = quantize_tensor(w, q=q, g=g, scale_dtype=dtype, fmt="dequant")
        assert deq.nbytes() == uni.nbytes()
        np.testing.assert_array_equal(np.asarray(deq.packed), np.asarray(uni.packed))


# ---------------------------------------------------------------------------
# kernels vs ref oracle (incl. the lane-padding path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("o", [128, 136])  # 136: no candidate block divides → pad
def test_kernel_matches_ref(rng, fmt, o):
    w = _w(rng, 256, o)
    x = jnp.asarray(rng.standard_normal((3, 256)), jnp.float32)
    qt = quantize_tensor(w, q=3, g=64, method="greedy", scale_dtype=jnp.float32, fmt=fmt)
    (y_ref,) = qmatmul(fmt, x, qt, impl="ref")
    for impl in get_format(fmt).impls:
        (y,) = qmatmul(fmt, x, qt, impl=impl, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


def test_unknown_impl_names_available(rng):
    qt = quantize_tensor(_w(rng), q=2, g=64, method="greedy", fmt="uniform")
    x = jnp.ones((1, 256), jnp.float32)
    with pytest.raises(ValueError, match="uniform_mm"):
        qmatmul("uniform", x, qt, impl="lutgemm", interpret=True)


def test_autotune_keys_carry_impl():
    """Per-format winners live under distinct table keys (the impl axis)."""
    k1 = make_key(8, 256, 128, 4, 64, "bcq_mm", "cpu-interpret")
    k2 = make_key(8, 256, 128, 4, 64, "uniform_mm", "cpu-interpret")
    assert k1 != k2
    bk, bo = get_blocks(
        B=8, k=256, o=128, q=4, g=64, impl="uniform_mm", interpret=True
    )
    assert bk and 256 % bk == 0 and bo and 128 % bo == 0


# ---------------------------------------------------------------------------
# cross-format differential: dequant vs uniform
# ---------------------------------------------------------------------------


def test_dequant_matmul_bitwise_equals_uniform_ref(rng):
    """Same packing + same reconstruction math → the ref paths are the same
    computation, bit for bit."""
    w = _w(rng)
    x = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
    qu = quantize_tensor(w, q=4, g=64, scale_dtype=jnp.float32, fmt="uniform")
    qd = quantize_tensor(w, q=4, g=64, scale_dtype=jnp.float32, fmt="dequant")
    (yu,) = qmatmul("uniform", x, qu, impl="ref")
    (yd,) = qmatmul("dequant", x, qd, impl="ref")
    np.testing.assert_array_equal(np.asarray(yu), np.asarray(yd))


def test_cross_format_greedy_tokens_identical():
    """The acceptance differential: a dequant-served model and a uniform-served
    model at the same (q, g) emit bit-identical greedy tokens end to end."""
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    prompts = _prompts(cfg, 2, 6)
    toks = {}
    for fmt in ("uniform", "dequant"):
        qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt=fmt))
        toks[fmt] = Engine(cfg, qp, max_seq=32).generate(prompts, 8).tokens
    np.testing.assert_array_equal(toks["uniform"], toks["dequant"])


# ---------------------------------------------------------------------------
# capabilities: fuse + truncate
# ---------------------------------------------------------------------------


def test_uniform_fused_decode_matches_unfused():
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt="uniform"))
    prompts = _prompts(cfg, 2, 6)
    fused = Engine(cfg, qp, max_seq=32, fuse=True).generate(prompts, 8)
    unfused = Engine(cfg, qp, max_seq=32, fuse=False).generate(prompts, 8)
    np.testing.assert_array_equal(fused.tokens, unfused.tokens)


def test_fuse_refuses_mixed_formats(rng):
    from repro.core import fuse_tensors

    w = _w(rng)
    qa = quantize_tensor(w, q=4, g=64, method="greedy", fmt="bcq")
    qb = quantize_tensor(w, q=4, g=64, fmt="uniform")
    with pytest.raises(ValueError, match="format mismatch"):
        fuse_tensors([qa, qb])


def test_truncate_capability_gating(rng):
    qt = quantize_tensor(_w(rng), q=4, g=64, fmt="uniform")
    with pytest.raises(ValueError, match="truncation"):
        qt.truncate(2)
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt="uniform"))
    with pytest.raises(ValueError, match="truncat"):
        truncate_params(qp, 2)
    eng = Engine(cfg, qp, max_seq=32)
    with pytest.raises(ValueError, match="bcq"):
        eng.generate(_prompts(cfg, 1, 6), 4, speculate=SpecConfig(2, 2))
    with pytest.raises(ValueError, match="bcq"):
        eng.init_slots(2, speculate=SpecConfig(2, 2))


def test_bcq_truncate_preserves_format(rng):
    qt = quantize_tensor(_w(rng), q=4, g=64, method="greedy")
    qd = qt.truncate(2)
    assert qd.fmt == "bcq" and qd.q == 2


# ---------------------------------------------------------------------------
# policies: mixed formats + struct trees
# ---------------------------------------------------------------------------


def test_mixed_format_policy_resolution():
    pol = QuantPolicy(q=4, g=128, attn=(2, 64, "uniform"), ffn=(4, 128))
    # legacy resolve keeps returning the raw entries (2-tuples stay 2-tuples)
    assert pol.resolve(("stages", "0", "b0", "mlp", "w_up")) == (4, 128)
    assert pol.resolve_fmt(("stages", "0", "b0", "attn", "wq")) == (2, 64, "uniform")
    assert pol.resolve_fmt(("stages", "0", "b0", "mlp", "w_up")) == (4, 128, "bcq")
    assert pol.resolve_fmt(("lm_head",)) == (4, 128, "bcq")
    assert pol.resolve_fmt(("stages", "0", "b0", "ln1")) is None


def test_mixed_format_model_decodes():
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(
        params,
        QuantPolicy(q=4, g=64, iters=2, attn=(4, 64, "uniform"), ffn=(3, 64, "bcq")),
    )
    fmts = {
        leaf.fmt
        for leaf in jax.tree.leaves(
            qp, is_leaf=lambda x: isinstance(x, QuantizedTensor)
        )
        if isinstance(leaf, QuantizedTensor)
    }
    assert fmts == {"uniform", "bcq"}
    res = Engine(cfg, qp, max_seq=32).generate(_prompts(cfg, 1, 6), 6)
    assert res.tokens.shape == (1, 12)


def test_quantized_structs_per_format():
    cfg = _small_cfg()
    structs = jax.eval_shape(lambda: init_params(KEY, cfg))
    for fmt, s_lead in (("bcq", 4), ("uniform", 2), ("dequant", 2)):
        qs = quantized_structs(structs, QuantPolicy(q=4, g=64, fmt=fmt))
        leaves = [
            l
            for l in jax.tree.leaves(
                qs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
            )
            if isinstance(l, QuantizedTensor)
        ]
        assert leaves, fmt
        for qt in leaves:
            assert qt.fmt == fmt
            assert qt.packed.shape[-3] == 4
            assert qt.packed.shape[-2] == qt.k // 8
            assert qt.scales.shape[-3] == s_lead


# ---------------------------------------------------------------------------
# TP placement via QuantFormat.tp_specs
# ---------------------------------------------------------------------------


def test_tp_specs_from_format(rng):
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import decode_tp_axes

    ax = decode_tp_axes(2)
    qt = quantize_tensor(_w(rng, 256, 128), q=4, g=64, fmt="uniform")
    spec = get_format("uniform").tp_specs(P("model", None), qt, ax)
    assert spec.fmt == "uniform"
    # k/8 = 32 and k/g = 4 both divide tp=2 → packed AND scales shard with k
    assert tuple(spec.packed) == (None, "model", None)
    assert tuple(spec.scales) == (None, "model", None)
    # an indivisible scale-group dim is dropped (caller decides to refuse)
    qt_odd = quantize_tensor(_w(rng, 192, 128), q=4, g=96, fmt="uniform")
    ax4 = decode_tp_axes(4)
    spec_odd = get_format("uniform").tp_specs(P("model", None), qt_odd, ax4)
    assert tuple(spec_odd.scales) == (None, None, None)  # k/g = 2, tp = 4


def test_relocalize_from_format(rng):
    qt = quantize_tensor(_w(rng, 256, 128), q=4, g=64, fmt="uniform")
    half = QuantizedTensor(
        packed=qt.packed[:, :16], scales=qt.scales[:, :2],
        g=qt.g, k=qt.k, o=qt.o, fmt=qt.fmt,
    )
    local = get_format("uniform").relocalize(half)
    assert (local.k, local.o, local.fmt) == (128, 128, "uniform")


# ---------------------------------------------------------------------------
# temperature-guard regression (Engine._sample / Request)
# ---------------------------------------------------------------------------


def test_sample_zero_temperature_falls_back_to_argmax(rng):
    logits = jnp.asarray(rng.standard_normal((3, 17)), jnp.float32)
    key = jax.random.PRNGKey(0)
    toks = _sample(logits, key, jnp.float32(0.0), greedy=False)
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, -1))
    )
    # and under jit with a traced temperature (the scan-body situation)
    toks_jit = jax.jit(lambda lg, k, t: _sample(lg, k, t, greedy=False))(
        logits, key, jnp.float32(0.0)
    )
    np.testing.assert_array_equal(np.asarray(toks_jit), np.asarray(toks))
    # positive temperatures keep the exact pre-guard stream
    t = jnp.float32(0.7)
    np.testing.assert_array_equal(
        np.asarray(_sample(logits, key, t, greedy=False)),
        np.asarray(jax.random.categorical(key, logits / t)),
    )


def test_request_validates_temperature():
    prompt = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError, match="finite"):
        Request(prompt=prompt, max_new_tokens=2, temperature=float("nan"))
    with pytest.raises(ValueError, match=">= 0"):
        Request(prompt=prompt, max_new_tokens=2, temperature=-1.0)
    assert Request(prompt=prompt, max_new_tokens=2, temperature=0.0).temperature == 0.0


def test_spec_parse_error_names_syntax():
    with pytest.raises(ValueError, match="QD:GAMMA"):
        SpecConfig.parse("nope")
    with pytest.raises(ValueError, match="QD:GAMMA"):
        SpecConfig.parse("2:4:6")
    with pytest.raises(ValueError, match="QD:GAMMA"):
        SpecConfig.parse("0:4")  # out-of-range still names the syntax


# ---------------------------------------------------------------------------
# serving e2e: every format through the scheduler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
def test_scheduler_serves_format(fmt):
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=3, g=64, iters=2, fmt=fmt))
    eng = Engine(cfg, qp, max_seq=32)
    prompts = _prompts(cfg, 2, 6)
    sched = Scheduler(eng, n_slots=2, chunk=4)
    rids = [
        sched.submit(Request(prompt=prompts[i], max_new_tokens=6, seed=i))
        for i in range(2)
    ]
    done = {c.rid: c for c in sched.run()}
    for i, rid in enumerate(rids):
        solo = eng.generate(prompts[i : i + 1], 6)
        np.testing.assert_array_equal(
            done[rid].new_tokens, solo.tokens[0, 6:], err_msg=fmt
        )
