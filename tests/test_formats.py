"""Pluggable quantization-format API (core/formats.py, DESIGN.md §2.4).

Per-format property tests (pack→dequant round-trip bounds, nbytes
accounting, registry errors), the cross-format differential (greedy tokens
for ``dequant`` vs ``uniform`` at the same (q, g) are bit-identical — same
packing, different kernel pipeline), capability gating (truncate/fuse), and
the temperature-guard regression for ``Engine._sample`` / ``Request``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    QuantizedTensor,
    format_names,
    get_format,
    pack_codes,
    quantize_tensor,
    unpack_codes,
)
from repro.data import MarkovCorpus
from repro.infer import Engine, Request, Scheduler, SpecConfig
from repro.infer.engine import _sample
from repro.kernels import qmatmul
from repro.kernels.autotune import get_blocks, make_key
from repro.models import init_params, reduced
from repro.quant import (
    QuantPolicy,
    quantize_params,
    quantized_structs,
    truncate_params,
)

KEY = jax.random.PRNGKey(0)
FORMATS = ("bcq", "uniform", "dequant", "codebook", "ternary")


def _w(rng, k=256, o=128):
    return jnp.asarray(rng.standard_normal((k, o)), jnp.float32)


def _small_cfg():
    return reduced(
        get_config("llama3.2-3b"), d_model=256, n_kv_heads=4, d_ff=512
    )


def _prompts(cfg, b, s, seed=3):
    return MarkovCorpus(cfg.vocab, seed=seed).sample(b, s, seed=7)[:, :s].astype(
        np.int32
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_names_and_lookup():
    assert set(FORMATS) <= set(format_names())
    for name in FORMATS:
        assert get_format(name).name == name
    with pytest.raises(ValueError, match="unknown quantization format"):
        get_format("nope")
    # the error names the registered formats so the fix is self-evident
    with pytest.raises(ValueError, match="bcq"):
        get_format("int3")


def test_quantize_tensor_tags_format(rng):
    w = _w(rng)
    for fmt in FORMATS:
        qt = quantize_tensor(w, q=4, g=64, method="greedy", fmt=fmt)
        assert qt.fmt == fmt
        assert qt.shape == (256, 128)
        assert qt.format() is get_format(fmt)


# ---------------------------------------------------------------------------
# pack → dequant round trips
# ---------------------------------------------------------------------------


def test_pack_unpack_codes_roundtrip(rng):
    for q in (2, 4, 8):
        codes = jnp.asarray(rng.integers(0, 2**q, (64, 24)), jnp.uint8)
        packed = pack_codes(codes, q)
        assert packed.shape == (q, 8, 24)
        assert packed.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(unpack_codes(packed)), codes)


def test_uniform_roundtrip_error_bound(rng):
    """Affine group quantization: |w - ŵ| <= scale/2 per element (f32 scales),
    with scale = (max - min) / (2^q - 1) per (group, column)."""
    w = _w(rng, k=256, o=64)
    g = 64
    for q in (2, 4, 8):
        qt = quantize_tensor(w, q=q, g=g, scale_dtype=jnp.float32, fmt="uniform")
        w_hat = qt.dequantize()
        grouped = np.asarray(w).reshape(256 // g, g, 64)
        scale = np.maximum(
            (grouped.max(1) - grouped.min(1)) / (2**q - 1), 1e-8
        )  # (G, o)
        err = np.abs(np.asarray(w_hat) - np.asarray(w)).reshape(256 // g, g, 64)
        assert np.all(err <= scale[:, None, :] * 0.5 + 1e-5), f"q={q}"


def test_roundtrip_error_monotone_in_q(rng):
    w = _w(rng)
    for fmt in ("bcq", "uniform"):
        errs = []
        for q in (2, 4, 8):
            qt = quantize_tensor(
                w, q=q, g=64, method="greedy", scale_dtype=jnp.float32, fmt=fmt
            )
            errs.append(
                float(jnp.linalg.norm(qt.dequantize() - w) / jnp.linalg.norm(w))
            )
        assert errs[0] > errs[1] > errs[2], (fmt, errs)


# ---------------------------------------------------------------------------
# nbytes accounting
# ---------------------------------------------------------------------------


def test_nbytes_accounting(rng):
    k, o, q, g = 256, 128, 4, 64
    w = _w(rng, k, o)
    for dtype, itemsize in ((jnp.float32, 4), (jnp.bfloat16, 2)):
        bcq = quantize_tensor(w, q=q, g=g, method="greedy", scale_dtype=dtype)
        assert bcq.nbytes() == q * (k // 8) * o + q * (k // g) * o * itemsize
        uni = quantize_tensor(w, q=q, g=g, scale_dtype=dtype, fmt="uniform")
        assert uni.nbytes() == q * (k // 8) * o + 2 * (k // g) * o * itemsize
        # dequant shares uniform's packing byte-for-byte
        deq = quantize_tensor(w, q=q, g=g, scale_dtype=dtype, fmt="dequant")
        assert deq.nbytes() == uni.nbytes()
        np.testing.assert_array_equal(np.asarray(deq.packed), np.asarray(uni.packed))
        # codebook: q index planes + the 2^q-entry centroid table per group
        cbk = quantize_tensor(w, q=q, g=g, iters=1, scale_dtype=dtype, fmt="codebook")
        assert cbk.nbytes() == q * (k // 8) * o + (2**q) * (k // g) * o * itemsize
        # ternary: 2 fixed planes + ONE alpha plane, whatever the policy's q
        ter = quantize_tensor(w, q=q, g=g, scale_dtype=dtype, fmt="ternary")
        assert ter.nbytes() == 2 * (k // 8) * o + (k // g) * o * itemsize


# ---------------------------------------------------------------------------
# kernels vs ref oracle (incl. the lane-padding path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("o", [128, 136])  # 136: no candidate block divides → pad
def test_kernel_matches_ref(rng, fmt, o):
    w = _w(rng, 256, o)
    x = jnp.asarray(rng.standard_normal((3, 256)), jnp.float32)
    qt = quantize_tensor(w, q=3, g=64, method="greedy", scale_dtype=jnp.float32, fmt=fmt)
    (y_ref,) = qmatmul(fmt, x, qt, impl="ref")
    for impl in get_format(fmt).impls:
        (y,) = qmatmul(fmt, x, qt, impl=impl, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


def test_unknown_impl_names_available(rng):
    qt = quantize_tensor(_w(rng), q=2, g=64, method="greedy", fmt="uniform")
    x = jnp.ones((1, 256), jnp.float32)
    with pytest.raises(ValueError, match="uniform_mm"):
        qmatmul("uniform", x, qt, impl="lutgemm", interpret=True)


def test_autotune_keys_carry_impl():
    """Per-format winners live under distinct table keys (the impl axis)."""
    k1 = make_key(8, 256, 128, 4, 64, "bcq_mm", "cpu-interpret")
    k2 = make_key(8, 256, 128, 4, 64, "uniform_mm", "cpu-interpret")
    assert k1 != k2
    bk, bo = get_blocks(
        B=8, k=256, o=128, q=4, g=64, impl="uniform_mm", interpret=True
    )
    assert bk and 256 % bk == 0 and bo and 128 % bo == 0


# ---------------------------------------------------------------------------
# cross-format differential: dequant vs uniform
# ---------------------------------------------------------------------------


def test_dequant_matmul_bitwise_equals_uniform_ref(rng):
    """Same packing + same reconstruction math → the ref paths are the same
    computation, bit for bit."""
    w = _w(rng)
    x = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
    qu = quantize_tensor(w, q=4, g=64, scale_dtype=jnp.float32, fmt="uniform")
    qd = quantize_tensor(w, q=4, g=64, scale_dtype=jnp.float32, fmt="dequant")
    (yu,) = qmatmul("uniform", x, qu, impl="ref")
    (yd,) = qmatmul("dequant", x, qd, impl="ref")
    np.testing.assert_array_equal(np.asarray(yu), np.asarray(yd))


def test_cross_format_greedy_tokens_identical():
    """The acceptance differential: a dequant-served model and a uniform-served
    model at the same (q, g) emit bit-identical greedy tokens end to end."""
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    prompts = _prompts(cfg, 2, 6)
    toks = {}
    for fmt in ("uniform", "dequant"):
        qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt=fmt))
        toks[fmt] = Engine(cfg, qp, max_seq=32).generate(prompts, 8).tokens
    np.testing.assert_array_equal(toks["uniform"], toks["dequant"])


# ---------------------------------------------------------------------------
# capabilities: fuse + truncate
# ---------------------------------------------------------------------------


def test_uniform_fused_decode_matches_unfused():
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt="uniform"))
    prompts = _prompts(cfg, 2, 6)
    fused = Engine(cfg, qp, max_seq=32, fuse=True).generate(prompts, 8)
    unfused = Engine(cfg, qp, max_seq=32, fuse=False).generate(prompts, 8)
    np.testing.assert_array_equal(fused.tokens, unfused.tokens)


def test_fuse_refuses_mixed_formats(rng):
    from repro.core import fuse_tensors

    w = _w(rng)
    qa = quantize_tensor(w, q=4, g=64, method="greedy", fmt="bcq")
    qb = quantize_tensor(w, q=4, g=64, fmt="uniform")
    with pytest.raises(ValueError, match="format mismatch"):
        fuse_tensors([qa, qb])


def test_truncate_capability_gating(rng):
    qt = quantize_tensor(_w(rng), q=4, g=64, fmt="uniform")
    with pytest.raises(ValueError, match="truncation"):
        qt.truncate(2)
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt="uniform"))
    with pytest.raises(ValueError, match="truncat"):
        truncate_params(qp, 2)
    eng = Engine(cfg, qp, max_seq=32)
    # the refusal names the capable formats from the registry's capability
    # flag (not a hardcoded list) — both bcq and ternary appear
    with pytest.raises(ValueError, match="bcq.*ternary"):
        eng.generate(_prompts(cfg, 1, 6), 4, speculate=SpecConfig(2, 2))
    with pytest.raises(ValueError, match="truncation-capable formats"):
        eng.init_slots(2, speculate=SpecConfig(2, 2))


def test_bcq_truncate_preserves_format(rng):
    qt = quantize_tensor(_w(rng), q=4, g=64, method="greedy")
    qd = qt.truncate(2)
    assert qd.fmt == "bcq" and qd.q == 2


# ---------------------------------------------------------------------------
# codebook: round-trip bounds + NF4 preset
# ---------------------------------------------------------------------------


def test_codebook_roundtrip_error_bound(rng):
    """k-means centroids at q=4 reconstruct a Gaussian weight to ~10% relative
    error; error is monotone in q (more centroids never hurt)."""
    w = _w(rng)
    errs = {}
    for q in (2, 4):
        qt = quantize_tensor(
            w, q=q, g=64, iters=4, scale_dtype=jnp.float32, fmt="codebook"
        )
        errs[q] = float(jnp.linalg.norm(qt.dequantize() - w) / jnp.linalg.norm(w))
    assert errs[4] < 0.15, errs
    assert errs[2] > errs[4], errs
    # every reconstructed value must BE one of the group's stored centroids
    qt = quantize_tensor(w, q=2, g=64, iters=2, scale_dtype=jnp.float32, fmt="codebook")
    wd = np.asarray(qt.dequantize()).reshape(256 // 64, 64, 128)
    cent = np.asarray(qt.scales)  # (4, G, o)
    match = np.abs(wd[None] - cent[:, :, None, :])  # (4, G, g, o)
    assert np.all(match.min(axis=0) < 1e-6)


def test_codebook_nf4_preset(rng):
    w = _w(rng)
    with pytest.raises(ValueError, match="nf4.*q=4"):
        quantize_tensor(w, q=3, g=64, method="nf4", fmt="codebook")
    qt = quantize_tensor(w, q=4, g=64, method="nf4", scale_dtype=jnp.float32,
                         fmt="codebook")
    err = float(jnp.linalg.norm(qt.dequantize() - w) / jnp.linalg.norm(w))
    assert err < 0.15
    # the NF4 grid contains 0 and ±absmax exactly: per (group, column) the
    # centroid table's extremes are ±max|w| and 0 is a table entry
    cent = np.asarray(qt.scales)  # (16, G, o)
    grouped = np.abs(np.asarray(w).reshape(256 // 64, 64, 128)).max(axis=1)
    np.testing.assert_allclose(cent.max(axis=0), grouped, rtol=1e-6)
    np.testing.assert_allclose(cent.min(axis=0), -grouped, rtol=1e-6)
    assert np.all(np.abs(cent).min(axis=0) < 1e-7)


# ---------------------------------------------------------------------------
# ternary: {-a, 0, +a} codes, masked-BCQ identity, nested drafts
# ---------------------------------------------------------------------------


def test_ternary_values_in_alphabet(rng):
    qt = quantize_tensor(_w(rng), q=4, g=64, scale_dtype=jnp.float32, fmt="ternary")
    assert qt.q == 2  # sign + mask planes, independent of the policy's q
    wd = np.asarray(qt.dequantize()).reshape(256 // 64, 64, 128)
    alpha = np.asarray(qt.scales)[0]  # (G, o)
    is_zero = np.abs(wd) < 1e-7
    is_alpha = np.abs(np.abs(wd) - alpha[:, None, :]) < 1e-5
    assert np.all(is_zero | is_alpha)
    assert is_zero.any() and is_alpha.any()  # both code classes occur


def test_ternary_truncate_bit_identity(rng):
    """Ternary is masked BCQ: the as_bcq view dequantizes bit-identically, and
    truncate(1) hands speculation a genuine 1-plane BCQ draft."""
    f = get_format("ternary")
    qt = quantize_tensor(_w(rng), q=4, g=64, scale_dtype=jnp.float32, fmt="ternary")
    bcq_view = f.as_bcq(qt)
    assert bcq_view.fmt == "bcq" and bcq_view.q == 2
    np.testing.assert_array_equal(
        np.asarray(f.dequantize(qt)),
        np.asarray(get_format("bcq").dequantize(bcq_view)),
    )
    draft = qt.truncate(1)
    assert draft.fmt == "bcq" and draft.q == 1
    np.testing.assert_array_equal(
        np.asarray(draft.packed[0]), np.asarray(bcq_view.packed[0])
    )
    assert qt.truncate(2) is qt  # full-width view is the tensor itself
    with pytest.raises(ValueError, match="1..2"):
        qt.truncate(3)


def test_ternary_speculative_decode_matches_plain():
    """Self-speculation through the sub-1-bit nested draft: greedy tokens stay
    bit-identical to the plain ternary engine (the acceptance criterion)."""
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=2, fmt="ternary"))
    eng = Engine(cfg, qp, max_seq=32)
    prompts = _prompts(cfg, 2, 6)
    plain = eng.generate(prompts, 8)
    spec = eng.generate(prompts, 8, speculate=SpecConfig(1, 2))
    np.testing.assert_array_equal(plain.tokens, spec.tokens)


# ---------------------------------------------------------------------------
# deploy-mode dispatch: no silent ref fallback
# ---------------------------------------------------------------------------


def test_deploy_mode_refuses_impl_less_format(rng):
    """Regression (the PR 9 bugfix): under impl_mode('deploy') a registered
    format with NO Pallas kernels used to fall through impl='auto' →
    resolve_impl → silent ref oracle — the deploy trace priced the wrong
    program. It must now raise, naming the format."""
    from repro.core import formats as formats_mod
    from repro.kernels.ops import impl_mode

    class StubFormat(formats_mod.QuantFormat):
        name = "stub-kernel-less"
        impls = ()

        def quantize(self, w, **kw):  # pragma: no cover - not reached
            raise NotImplementedError

        def dequantize(self, qt, dtype=jnp.float32):
            return jnp.zeros((qt.k, qt.o), dtype)

        def matvec(self, xb, qt, *, impl, interpret):  # pragma: no cover
            raise NotImplementedError

    formats_mod.register_format(StubFormat())
    try:
        base = quantize_tensor(_w(rng), q=2, g=64, fmt="uniform")
        qt = QuantizedTensor(
            packed=base.packed, scales=base.scales,
            g=base.g, k=base.k, o=base.o, fmt="stub-kernel-less",
        )
        x = jnp.ones((1, 256), jnp.float32)
        # outside deploy mode the stub happily serves its ref oracle
        (y,) = qmatmul("stub-kernel-less", x, qt, impl="ref")
        assert y.shape == (1, 128)
        with impl_mode("deploy"):
            with pytest.raises(ValueError, match="stub-kernel-less.*deploy"):
                qmatmul("stub-kernel-less", x, qt)
        # explicit impl choices still win over the mode
        with impl_mode("deploy"):
            (y2,) = qmatmul("stub-kernel-less", x, qt, impl="ref")
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y))
    finally:
        del formats_mod._REGISTRY["stub-kernel-less"]


# ---------------------------------------------------------------------------
# packing edge cases + the shared scales-block-rows helper
# ---------------------------------------------------------------------------


def test_pack_codes_ragged_k_raises(rng):
    codes = jnp.asarray(rng.integers(0, 4, (60, 16)), jnp.uint8)  # k % 8 != 0
    with pytest.raises(ValueError, match="multiple of 8"):
        pack_codes(codes, 2)


def test_scales_block_rows_matches_kernel_blockspecs():
    """The shared helper IS the scales-rows rule every kernel's BlockSpec
    encodes (g <= block_k → block_k//g rows; g > block_k → 1 row), checked
    across every (block_k, g) pair the tiling validator admits — so the VMEM
    estimators and the kernels can never disagree on the scales block."""
    from repro.kernels.introspect import scales_block_rows

    checked = 0
    for block_k in (64, 128, 256, 512, 1024):
        for g in (8, 16, 24, 48, 64, 128, 256, 512, 2048):
            if g % 8 or not (block_k % g == 0 or g % block_k == 0):
                continue  # the kernels' _validate_tiling rejects these
            expected = block_k // g if g <= block_k else 1
            assert scales_block_rows(block_k, g) == expected, (block_k, g)
            checked += 1
    assert checked > 10


@pytest.mark.parametrize("fmt", ("codebook", "ternary"))
def test_new_format_kernel_matches_ref_group_spans_blocks(rng, fmt):
    """g > block_k: one scale group spans several k-blocks — the (S, 1, bo)
    BlockSpec arm, pinned explicitly via block_k=128 against g=512."""
    from repro.kernels.codebook_mm import codebook_mm
    from repro.kernels.ternary_mm import ternary_mm

    w = _w(rng, 512, 128)
    x = jnp.asarray(rng.standard_normal((8, 512)), jnp.float32)
    qt = quantize_tensor(
        w, q=2, g=512, iters=2, scale_dtype=jnp.float32, fmt=fmt
    )
    (y_ref,) = qmatmul(fmt, x, qt, impl="ref")
    fn = {"codebook": codebook_mm, "ternary": ternary_mm}[fmt]
    y = fn(x, qt.packed, qt.scales, g=512, block_k=128, block_o=128,
           interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


@pytest.mark.parametrize("fmt", ("codebook", "ternary"))
def test_new_format_tp_specs(rng, fmt):
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import decode_tp_axes

    ax = decode_tp_axes(2)
    qt = quantize_tensor(_w(rng, 256, 128), q=2, g=64, iters=1, fmt=fmt)
    spec = get_format(fmt).tp_specs(P("model", None), qt, ax)
    assert spec.fmt == fmt
    # k/8 = 32 and k/g = 4 both divide tp=2 → packed AND scales shard with k
    assert tuple(spec.packed) == (None, "model", None)
    assert tuple(spec.scales) == (None, "model", None)


# ---------------------------------------------------------------------------
# policies: mixed formats + struct trees
# ---------------------------------------------------------------------------


def test_mixed_format_policy_resolution():
    pol = QuantPolicy(q=4, g=128, attn=(2, 64, "uniform"), ffn=(4, 128))
    # legacy resolve keeps returning the raw entries (2-tuples stay 2-tuples)
    assert pol.resolve(("stages", "0", "b0", "mlp", "w_up")) == (4, 128)
    assert pol.resolve_fmt(("stages", "0", "b0", "attn", "wq")) == (2, 64, "uniform")
    assert pol.resolve_fmt(("stages", "0", "b0", "mlp", "w_up")) == (4, 128, "bcq")
    assert pol.resolve_fmt(("lm_head",)) == (4, 128, "bcq")
    assert pol.resolve_fmt(("stages", "0", "b0", "ln1")) is None


def test_mixed_format_model_decodes():
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(
        params,
        QuantPolicy(q=4, g=64, iters=2, attn=(4, 64, "uniform"), ffn=(3, 64, "bcq")),
    )
    fmts = {
        leaf.fmt
        for leaf in jax.tree.leaves(
            qp, is_leaf=lambda x: isinstance(x, QuantizedTensor)
        )
        if isinstance(leaf, QuantizedTensor)
    }
    assert fmts == {"uniform", "bcq"}
    res = Engine(cfg, qp, max_seq=32).generate(_prompts(cfg, 1, 6), 6)
    assert res.tokens.shape == (1, 12)


def test_quantized_structs_per_format():
    cfg = _small_cfg()
    structs = jax.eval_shape(lambda: init_params(KEY, cfg))
    # (fmt, packed planes at policy q=4, scales lead dim)
    for fmt, planes, s_lead in (
        ("bcq", 4, 4),
        ("uniform", 4, 2),
        ("dequant", 4, 2),
        ("codebook", 4, 16),
        ("ternary", 2, 1),  # ternary stores 2 planes whatever q says
    ):
        qs = quantized_structs(structs, QuantPolicy(q=4, g=64, fmt=fmt))
        leaves = [
            l
            for l in jax.tree.leaves(
                qs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
            )
            if isinstance(l, QuantizedTensor)
        ]
        assert leaves, fmt
        for qt in leaves:
            assert qt.fmt == fmt
            assert qt.packed.shape[-3] == planes
            assert qt.packed.shape[-2] == qt.k // 8
            assert qt.scales.shape[-3] == s_lead


# ---------------------------------------------------------------------------
# TP placement via QuantFormat.tp_specs
# ---------------------------------------------------------------------------


def test_tp_specs_from_format(rng):
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import decode_tp_axes

    ax = decode_tp_axes(2)
    qt = quantize_tensor(_w(rng, 256, 128), q=4, g=64, fmt="uniform")
    spec = get_format("uniform").tp_specs(P("model", None), qt, ax)
    assert spec.fmt == "uniform"
    # k/8 = 32 and k/g = 4 both divide tp=2 → packed AND scales shard with k
    assert tuple(spec.packed) == (None, "model", None)
    assert tuple(spec.scales) == (None, "model", None)
    # an indivisible scale-group dim is dropped (caller decides to refuse)
    qt_odd = quantize_tensor(_w(rng, 192, 128), q=4, g=96, fmt="uniform")
    ax4 = decode_tp_axes(4)
    spec_odd = get_format("uniform").tp_specs(P("model", None), qt_odd, ax4)
    assert tuple(spec_odd.scales) == (None, None, None)  # k/g = 2, tp = 4


def test_relocalize_from_format(rng):
    qt = quantize_tensor(_w(rng, 256, 128), q=4, g=64, fmt="uniform")
    half = QuantizedTensor(
        packed=qt.packed[:, :16], scales=qt.scales[:, :2],
        g=qt.g, k=qt.k, o=qt.o, fmt=qt.fmt,
    )
    local = get_format("uniform").relocalize(half)
    assert (local.k, local.o, local.fmt) == (128, 128, "uniform")


# ---------------------------------------------------------------------------
# temperature-guard regression (Engine._sample / Request)
# ---------------------------------------------------------------------------


def test_sample_zero_temperature_falls_back_to_argmax(rng):
    logits = jnp.asarray(rng.standard_normal((3, 17)), jnp.float32)
    key = jax.random.PRNGKey(0)
    toks = _sample(logits, key, jnp.float32(0.0), greedy=False)
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, -1))
    )
    # and under jit with a traced temperature (the scan-body situation)
    toks_jit = jax.jit(lambda lg, k, t: _sample(lg, k, t, greedy=False))(
        logits, key, jnp.float32(0.0)
    )
    np.testing.assert_array_equal(np.asarray(toks_jit), np.asarray(toks))
    # positive temperatures keep the exact pre-guard stream
    t = jnp.float32(0.7)
    np.testing.assert_array_equal(
        np.asarray(_sample(logits, key, t, greedy=False)),
        np.asarray(jax.random.categorical(key, logits / t)),
    )


def test_request_validates_temperature():
    prompt = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError, match="finite"):
        Request(prompt=prompt, max_new_tokens=2, temperature=float("nan"))
    with pytest.raises(ValueError, match=">= 0"):
        Request(prompt=prompt, max_new_tokens=2, temperature=-1.0)
    assert Request(prompt=prompt, max_new_tokens=2, temperature=0.0).temperature == 0.0


def test_spec_parse_error_names_syntax():
    with pytest.raises(ValueError, match="QD:GAMMA"):
        SpecConfig.parse("nope")
    with pytest.raises(ValueError, match="QD:GAMMA"):
        SpecConfig.parse("2:4:6")
    with pytest.raises(ValueError, match="QD:GAMMA"):
        SpecConfig.parse("0:4")  # out-of-range still names the syntax


# ---------------------------------------------------------------------------
# serving e2e: every format through the scheduler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
def test_scheduler_serves_format(fmt):
    cfg = _small_cfg()
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=3, g=64, iters=2, fmt=fmt))
    eng = Engine(cfg, qp, max_seq=32)
    prompts = _prompts(cfg, 2, 6)
    sched = Scheduler(eng, n_slots=2, chunk=4)
    rids = [
        sched.submit(Request(prompt=prompts[i], max_new_tokens=6, seed=i))
        for i in range(2)
    ]
    done = {c.rid: c for c in sched.run()}
    for i, rid in enumerate(rids):
        solo = eng.generate(prompts[i : i + 1], 6)
        np.testing.assert_array_equal(
            done[rid].new_tokens, solo.tokens[0, 6:], err_msg=fmt
        )
