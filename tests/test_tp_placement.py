"""TP placement + QuantizedTensor spec derivation properties (ISSUE 4).

Three layers of guarantees:

1. `qt_specs_like` (generic GSPMD rules): for EVERY config in `configs/`,
   every quantized leaf derives packed/scales specs whose sharded dims divide
   their mesh axes exactly, or fall back to replicated — never a misaligned
   shard.
2. `tp_param_specs` (the strict shard_map rules): every leaf of a real
   (fused, quantized) decode tree gets a spec; dims that MUST shard divide
   exactly — non-divisibility raises, naming the leaf (`test_tp_serve.py`
   holds the engine-level versions of those error paths).
3. `shard_model` round-trip: a device_get of the placed tree is bit-identical
   to the unsharded tree (fused leaves modulo the documented column
   re-interleave, which is itself a permutation).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

# hypothesis is an optional test extra (see pyproject [test]); deterministic
# fallbacks below keep coverage on minimal installs (same pattern as test_bcq)
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.configs import ARCH_IDS, get_config
from repro.core.qtensor import QuantizedTensor
from repro.models import init_params, reduced
from repro.models.config import ModelConfig
from repro.parallel import cache_specs, decode_tp_axes, param_specs, single_pod_axes
from repro.parallel.sharding import qt_specs_like
from repro.parallel.tp import (
    _interleave_perm,
    make_tp_mesh,
    relayout_fused_for_tp,
    shard_model,
    tp_param_specs,
)
from repro.quant import QuantPolicy, quantize_params, quantized_structs

KEY = jax.random.PRNGKey(0)


def _qt_leaves_with_specs(tree, specs):
    """Pairs of (path, QuantizedTensor struct, dense PartitionSpec)."""
    out = []

    def visit(path, leaf, spec):
        if isinstance(leaf, QuantizedTensor):
            out.append((jax.tree_util.keystr(path), leaf, spec))
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, tree, specs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )
    return out


def _assert_divisible_or_replicated(shape, spec, ax, where):
    assert len(tuple(spec)) <= len(shape), f"{where}: rank mismatch {spec} {shape}"
    for dim, axis in zip(shape, tuple(spec)):
        if axis is None:
            continue
        assert dim % ax.size(axis) == 0, (
            f"{where}: dim {dim} not divisible by {axis}={ax.size(axis)}"
        )


# ---------------------------------------------------------------------------
# 1. qt_specs_like across the whole config zoo (full published shapes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_qt_specs_for_every_config(arch):
    """Every quantized leaf of every config gets packed/scales specs that
    divide their axes or replicate — the paper's group-wise-quantization-
    shards-cleanly claim, checked structurally at full size (structs only)."""
    cfg = get_config(arch)
    ax = single_pod_axes()
    structs = jax.eval_shape(lambda: init_params(KEY, cfg))
    qstructs = quantized_structs(structs, QuantPolicy(q=4, g=128))
    specs = param_specs(cfg, ax)
    triples = _qt_leaves_with_specs(qstructs, specs)
    assert triples, f"{arch}: quantization produced no QuantizedTensor leaves"
    for where, qt, dense_spec in triples:
        spec = qt_specs_like(dense_spec, qt, ax)
        _assert_divisible_or_replicated(
            qt.packed.shape, spec.packed, ax, f"{arch}{where}/packed"
        )
        _assert_divisible_or_replicated(
            qt.scales.shape, spec.scales, ax, f"{arch}{where}/scales"
        )
        # o is shared between planes: both shard it identically
        assert tuple(spec.packed)[-1] == tuple(spec.scales)[-1]


def _qt_specs_property(k, o, g, q, tp):
    """qt_specs_like on a (k, o) weight sharded (None, model): packed o always
    shards when divisible; scales k-group dim shards iff (k/g) % tp == 0."""
    ax = decode_tp_axes(tp)
    qt = QuantizedTensor(
        packed=jax.ShapeDtypeStruct((q, k // 8, o), jnp.uint8),
        scales=jax.ShapeDtypeStruct((q, k // g, o), jnp.bfloat16),
        g=g, k=k, o=o,
    )
    spec = qt_specs_like(P("model", None), qt, ax)
    expect_pk = "model" if (k // 8) % tp == 0 else None
    expect_sk = "model" if (k // g) % tp == 0 else None
    assert tuple(spec.packed) == (None, expect_pk, None)
    assert tuple(spec.scales) == (None, expect_sk, None)
    _assert_divisible_or_replicated(qt.packed.shape, spec.packed, ax, "packed")
    _assert_divisible_or_replicated(qt.scales.shape, spec.scales, ax, "scales")


_FALLBACK_SHAPES = [
    (128, 64, 32, 3, 2),
    (256, 128, 128, 4, 4),
    (128, 256, 128, 2, 2),  # k/g=1: scales must replicate
    (192, 128, 24, 4, 4),  # k/8=24 divisible, k/g=8 divisible
]

if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        kc=st.integers(2, 64),
        o=st.sampled_from([64, 128, 256]),
        gmul=st.sampled_from([1, 2, 4, 8]),
        q=st.integers(1, 4),
        tp=st.sampled_from([2, 4]),
    )
    def test_qt_specs_like_property(kc, o, gmul, q, tp):
        k = kc * 8
        g = min(8 * gmul, k)
        if k % g:
            g = 8
        _qt_specs_property(k, o, g, q, tp)

else:

    @pytest.mark.parametrize("k,o,g,q,tp", _FALLBACK_SHAPES)
    def test_qt_specs_like_property(k, o, g, q, tp):
        _qt_specs_property(k, o, g, q, tp)


# ---------------------------------------------------------------------------
# 2. strict TP specs on a real decode tree
# ---------------------------------------------------------------------------


def _tp_cfg():
    return reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)


@functools.lru_cache(maxsize=None)
def _tp_tree(q: int, fused: bool):
    from repro.models.fuse import fuse_decode_projections

    cfg = _tp_cfg()
    params = init_params(KEY, cfg)
    if q:
        params = quantize_params(params, QuantPolicy(q=q, g=32, iters=1))
    if fused:
        params = fuse_decode_projections(cfg, params)
    return cfg, params


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("fused", [False, True], ids=["unfused", "fused"])
def test_tp_specs_cover_every_leaf_and_divide(tp, fused):
    cfg, params = _tp_tree(4, fused)
    ax = decode_tp_axes(tp)
    tree = relayout_fused_for_tp(cfg, params, tp)
    specs = tp_param_specs(cfg, tree, ax)
    assert jax.tree.structure(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    ) == jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, QuantizedTensor))

    def visit(path, leaf, spec):
        where = jax.tree_util.keystr(path)
        name = str(getattr(path[-1], "key", path[-1]))
        if isinstance(leaf, QuantizedTensor):
            _assert_divisible_or_replicated(leaf.packed.shape, spec.packed, ax, where)
            _assert_divisible_or_replicated(leaf.scales.shape, spec.scales, ax, where)
            planes = (tuple(spec.packed), tuple(spec.scales))
        else:
            _assert_divisible_or_replicated(leaf.shape, spec, ax, where)
            planes = (tuple(spec),)
        # strictness: weight leaves MUST shard (no silent replication)
        if name in ("wq", "wk", "wv", "wqkv", "w_gate", "w_up", "w_gate_up",
                    "lm_head", "wo", "w_down"):
            for pl in planes:
                assert "model" in pl, f"{where}: silently replicated ({pl})"
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, tree, specs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )


def test_tp_specs_raise_naming_leaf_on_bad_group():
    """The latent `_wspec`/`_maybe` silent-replication fallback is an error in
    the TP path: k/g=1 at tp=2 must raise and say which leaf and which dim."""
    cfg, params = _tp_tree(0, False)
    params = quantize_params(params, QuantPolicy(q=2, g=128, iters=1))
    with pytest.raises(ValueError) as ei:
        tp_param_specs(cfg, params, decode_tp_axes(2))
    msg = str(ei.value)
    assert "wo" in msg and "k/g" in msg and "replicated" in msg


def test_fused_relayout_rejects_odd_split():
    """o_total must split per-projection: kv_dim=128 at tp=3 (non-divisor)
    raises, naming the fused leaf."""
    cfg, params = _tp_tree(0, True)
    with pytest.raises(ValueError, match="wqkv"):
        relayout_fused_for_tp(cfg, params, 3)


def test_interleave_perm_is_exact_reshard():
    """The fused-column permutation is a bijection, and slicing the permuted
    columns into tp contiguous shards hands shard d exactly [q_d | k_d | v_d]."""
    out_dims, tp = (12, 8, 8), 4
    perm = _interleave_perm(out_dims, tp)
    assert sorted(perm.tolist()) == list(range(sum(out_dims)))
    shard = np.split(perm, tp)
    starts = np.cumsum([0] + list(out_dims[:-1]))
    for d in range(tp):
        expect = np.concatenate(
            [st + d * (dim // tp) + np.arange(dim // tp)
             for st, dim in zip(starts, out_dims)]
        )
        np.testing.assert_array_equal(shard[d], expect)


# ---------------------------------------------------------------------------
# 3. placed-tree round trip (real devices)
# ---------------------------------------------------------------------------


@pytest.mark.needs_multidevice
@pytest.mark.parametrize("tp", [2, 4])
def test_shard_model_roundtrip_unfused(tp):
    """device_get of every placed leaf equals the unsharded original bit-for-
    bit (no fused leaves → no re-layout, the tree is untouched)."""
    cfg, params = _tp_tree(4, False)
    placed, tpc = shard_model(cfg, params, make_tp_mesh(tp))
    ref = jax.tree.leaves(params)
    got = jax.tree.leaves(placed)
    assert len(ref) == len(got)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(jax.device_get(g)), np.asarray(r))


@pytest.mark.needs_multidevice
def test_shard_model_roundtrip_fused_is_permutation():
    """Fused leaves round-trip modulo the documented column re-interleave:
    inverting the permutation recovers the original wqkv columns."""
    tp = 2
    cfg, params = _tp_tree(4, True)
    placed, _ = shard_model(cfg, params, make_tp_mesh(tp))
    orig = params["stages"][0]["b0"]["attn"]["wqkv"]
    got = placed["stages"][0]["b0"]["attn"]["wqkv"]
    perm = _interleave_perm((cfg.q_dim, cfg.kv_dim, cfg.kv_dim), tp)
    inv = np.argsort(perm)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(got.packed))[..., inv], np.asarray(orig.packed)
    )
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(got.scales))[..., inv], np.asarray(orig.scales)
    )


# ---------------------------------------------------------------------------
# cache layouts + TP axes plumbing
# ---------------------------------------------------------------------------


def test_cache_specs_heads_layout():
    cfg = _tp_cfg()
    specs = cache_specs(cfg, decode_tp_axes(2), 1, layout="heads")
    s = specs["stages"][0]["b0"]["k"]
    assert tuple(s) == (None, None, None, "model", None)
    # the GSPMD decode layout is untouched
    s_dh = cache_specs(cfg, single_pod_axes(), 128)["stages"][0]["b0"]["k"]
    assert tuple(s_dh)[-1] == "model" and tuple(s_dh)[-2] is None
    with pytest.raises(ValueError):
        cache_specs(cfg, decode_tp_axes(2), 1, layout="nope")


def test_cache_specs_heads_layout_int8():
    cfg = ModelConfig(
        name="t-int8", family="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=256, kv_cache_dtype="int8",
    )
    specs = cache_specs(cfg, decode_tp_axes(4), 1, layout="heads")
    blk = specs["stages"][0]["b0"]
    assert tuple(blk["k_scale"]) == (None, None, None, "model")
    assert tuple(blk["v"]) == (None, None, None, "model", None)


def test_decode_tp_axes_shapes():
    ax = decode_tp_axes(4)
    assert ax.dp == () and ax.fsdp is None and ax.model == "model"
    assert ax.data_size == 1 and ax.model_size == 4
    # empty dp must normalise to None, never P(()), in batch/cache specs
    cfg = _tp_cfg()
    bs = __import__("repro.parallel", fromlist=["batch_specs"]).batch_specs(cfg, ax, 4)
    assert tuple(bs["tokens"]) == (None, None)
    cs = cache_specs(cfg, ax, 4, layout="heads")
    assert tuple(cs["stages"][0]["b0"]["k"])[1] is None
