"""Recurrent-cell math: chunkwise mLSTM == quadratic; RG-LRU scan == stepwise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

import repro.models.recurrent as R


def _qkvg(seed, b=2, nh=2, s=256, dh=16):
    r = np.random.default_rng(seed)
    q, k, v = (jnp.asarray(r.standard_normal((b, nh, s, dh)), jnp.float32) for _ in range(3))
    ig = jnp.asarray(r.standard_normal((b, nh, s)), jnp.float32)
    fg = jnp.asarray(r.standard_normal((b, nh, s)) + 2.0, jnp.float32)
    return q, k, v, ig, fg


def _check_mlstm_chunkwise_equals_quadratic(seed, chunk):
    """Shared body: hypothesis sweep and deterministic fallback can't drift."""
    q, k, v, ig, fg = _qkvg(seed)
    h_quad = R._mlstm_parallel(q, k, v, ig, fg)
    h_chunk = R._mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(h_chunk), np.asarray(h_quad), rtol=3e-4, atol=3e-4
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([32, 64, 128]))
    def test_mlstm_chunkwise_equals_quadratic(seed, chunk):
        _check_mlstm_chunkwise_equals_quadratic(seed, chunk)

else:

    @pytest.mark.parametrize("seed,chunk", [(0, 32), (1, 64), (2, 128)])
    def test_mlstm_chunkwise_equals_quadratic(seed, chunk):
        _check_mlstm_chunkwise_equals_quadratic(seed, chunk)


def test_mlstm_chunkwise_pad_path():
    q, k, v, ig, fg = _qkvg(7, s=300)
    h_quad = R._mlstm_parallel(q, k, v, ig, fg)
    h_chunk = R._mlstm_chunkwise(q, k, v, ig, fg, chunk=128)
    np.testing.assert_allclose(
        np.asarray(h_chunk), np.asarray(h_quad), rtol=3e-4, atol=3e-4
    )


def test_rglru_scan_equals_stepwise():
    """Running the RG-LRU scan over S equals S single-step invocations."""
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="t", family="hybrid", n_layers=3, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=32, vocab=64, lru_width=16,
        param_dtype="float32", compute_dtype="float32",
    )
    p = R.init_rglru(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(3)
    x = jnp.asarray(r.standard_normal((2, 12, 16)), jnp.float32)

    full, _ = R.rglru_block(p, cfg, x, state=None)

    state = {
        "h": jnp.zeros((2, 16), jnp.float32),
        "conv": jnp.zeros((2, cfg.conv_width - 1, 16), jnp.float32),
    }
    outs = []
    for t in range(12):
        o, state = R.rglru_block(p, cfg, x[:, t : t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=1e-4, atol=1e-4)


def test_mlstm_block_state_continuation():
    """Splitting a sequence across two stateful calls == one call."""
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="t", family="ssm", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=64, param_dtype="float32", compute_dtype="float32",
    )
    p = R.init_mlstm(jax.random.PRNGKey(1), cfg)
    r = np.random.default_rng(5)
    x = jnp.asarray(r.standard_normal((2, 10, 16)), jnp.float32)
    inner = int(16 * cfg.mlstm_proj_factor)
    dh = inner // 2
    st0 = {
        "c": jnp.zeros((2, 2, dh, dh), jnp.float32),
        "n": jnp.zeros((2, 2, dh), jnp.float32),
        "m": jnp.full((2, 2), -jnp.inf, jnp.float32),
    }
    full, _ = R.mlstm_block(p, cfg, x, dict(st0))
    o1, st1 = R.mlstm_block(p, cfg, x[:, :6], dict(st0))
    o2, _ = R.mlstm_block(p, cfg, x[:, 6:], st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([o1, o2], 1)), np.asarray(full),
        rtol=2e-4, atol=2e-4,
    )


def test_slstm_stability_long_sequence():
    """Exponential gating must not overflow on long inputs (log-space m)."""
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="t", family="ssm", n_layers=2, d_model=8, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=64, param_dtype="float32", compute_dtype="float32",
    )
    p = R.init_slstm(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(8).standard_normal((1, 512, 8)) * 4, jnp.float32)
    out, _ = R.slstm_block(p, cfg, x, None)
    assert not bool(jnp.isnan(out).any())
    assert not bool(jnp.isinf(out).any())
