"""Continuous-batching scheduler (ISSUE 2 tentpole): the interleaving must be
invisible — every request's tokens are identical to a solo batch-1
``Engine.generate`` with the same prompt/temperature/seed, no matter how
requests are interleaved, admitted mid-flight, or how slots are reused."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import Engine, Request, Scheduler
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params

KEY = jax.random.PRNGKey(0)


def _requests(cfg, n, *, seed=0, min_len=4, max_len=12, min_gen=3, max_gen=14):
    """Mixed lengths, mixed greedy/sampled temperatures, per-request seeds."""
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(min_len, max_len))
        prompt = corpus.sample(1, plen, seed=100 + i)[0, :plen].astype(np.int32)
        out.append(
            Request(
                prompt=prompt,
                max_new_tokens=int(rng.integers(min_gen, max_gen)),
                temperature=[0.0, 1.0, 0.7][i % 3],
                seed=10 + i,
            )
        )
    return out


def _assert_identical_to_solo(eng, reqs, done):
    for r in reqs:
        solo = eng.generate(
            r.prompt[None], r.max_new_tokens, temperature=r.temperature, seed=r.seed
        )
        np.testing.assert_array_equal(
            solo.tokens[0, r.prompt.size :],
            done[r.rid].new_tokens,
            err_msg=f"request {r.rid} diverged from solo generate",
        )
        np.testing.assert_array_equal(done[r.rid].tokens[: r.prompt.size], r.prompt)


@pytest.mark.parametrize("quantized", [False, True], ids=["dense", "bcq_q3"])
def test_continuous_batching_token_identical(quantized):
    """The big invariant, for a dense and a BCQ-quantized model: 6 requests
    through 3 slots (so half are admitted mid-flight into freed slots),
    mixed prompt lengths and mixed greedy/sampled temperatures."""
    cfg = reduced(get_config("llama3.2-3b"))
    params = init_params(KEY, cfg)
    if quantized:
        params = quantize_params(params, QuantPolicy(q=3, g=64, iters=2))
    eng = Engine(cfg, params, max_seq=48)
    reqs = _requests(cfg, 6)

    sched = Scheduler(eng, n_slots=3, chunk=4)
    for r in reqs:
        sched.submit(r)
    done = {c.rid: c for c in sched.run()}

    assert len(done) == len(reqs)
    # with 6 requests and 3 slots, at least one admission happened mid-flight
    assert max(c.admitted_at_step for c in done.values()) > 0
    _assert_identical_to_solo(eng, reqs, done)


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "xlstm-125m"])
def test_continuous_batching_recurrent_and_window(arch):
    """Slot independence also holds for recurrent state (rglru/mlstm/slstm)
    and local-attention ring caches — admission resets the whole slot row."""
    cfg = reduced(get_config(arch))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=40)
    reqs = _requests(cfg, 4, max_len=10, max_gen=9)
    sched = Scheduler(eng, n_slots=2, chunk=3)
    for r in reqs:
        sched.submit(r)
    done = {c.rid: c for c in sched.run()}
    _assert_identical_to_solo(eng, reqs, done)


def test_slot_reuse_does_not_leak_state():
    """The same request replayed as the 1st and last tenant of a heavily
    reused slot pool must emit identical tokens (slot-reset contract)."""
    cfg = reduced(get_config("llama3.2-3b"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=40)
    corpus = MarkovCorpus(cfg.vocab, seed=5)
    prompt = corpus.sample(1, 6, seed=1)[0, :6].astype(np.int32)
    twin = dict(prompt=prompt, max_new_tokens=8, temperature=1.0, seed=99)

    sched = Scheduler(eng, n_slots=2, chunk=2)
    first = sched.submit(Request(**twin))
    for r in _requests(cfg, 5, seed=7, max_len=8, max_gen=8):
        sched.submit(r)
    last = sched.submit(Request(**twin))
    done = {c.rid: c for c in sched.run()}
    np.testing.assert_array_equal(done[first].new_tokens, done[last].new_tokens)


def test_mid_chunk_completion_and_budgets():
    """A request finishing mid-chunk stops emitting exactly at its budget
    while neighbours keep decoding; every completion has exact length."""
    cfg = reduced(get_config("llama3.2-3b"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=40)
    corpus = MarkovCorpus(cfg.vocab, seed=9)
    p = corpus.sample(2, 5, seed=2).astype(np.int32)
    sched = Scheduler(eng, n_slots=2, chunk=8)  # budgets 3 and 13 straddle chunks
    a = sched.submit(Request(prompt=p[0, :5], max_new_tokens=3))
    b = sched.submit(Request(prompt=p[1, :5], max_new_tokens=13))
    done = {c.rid: c for c in sched.run()}
    assert done[a].new_tokens.shape == (3,)
    assert done[b].new_tokens.shape == (13,)
    assert done[a].finished_at_step < done[b].finished_at_step
    # utilisation bookkeeping: exactly the emitted tokens were active steps
    assert sched.steps_active == 3 + 13


def test_chunk_one_matches_larger_chunks():
    """Chunk size is a latency/throughput knob, never a semantics knob."""
    cfg = reduced(get_config("llama3.2-3b"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=40)
    reqs = _requests(cfg, 4, seed=11, max_len=8, max_gen=8)

    outs = []
    for chunk in (1, 5):
        sched = Scheduler(eng, n_slots=2, chunk=chunk)
        rids = [sched.submit(Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                                     temperature=r.temperature, seed=r.seed))
                for r in reqs]
        done = {c.rid: c for c in sched.run()}
        outs.append([done[rid].new_tokens for rid in rids])
    for x, y in zip(*outs):
        np.testing.assert_array_equal(x, y)


def test_scheduler_validation():
    cfg = reduced(get_config("llama3.2-3b"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=16)
    sched = Scheduler(eng, n_slots=2, chunk=2)
    with pytest.raises(ValueError):
        Request(prompt=np.zeros((0,), np.int32), max_new_tokens=4)
    with pytest.raises(ValueError):
        Request(prompt=np.zeros((4,), np.int32), max_new_tokens=0)
    with pytest.raises(ValueError):  # prompt+gen exceeds the engine's cache
        sched.submit(Request(prompt=np.zeros((10,), np.int32), max_new_tokens=10))
    with pytest.raises(ValueError):
        Scheduler(eng, n_slots=0)
    assert sched.idle and sched.step() == []
